//! Golden-parity harness for the blocked kernel layer (DESIGN.md §5).
//!
//! Four layers of checks, bottom-up:
//!
//! 1. Blocked GEMM / GEMM-transpose match the retained naive reference
//!    within 1e-5 relative over random M/N/K — including K = 0, M = 1,
//!    non-multiple-of-tile sizes and K straddling the `KC` tile — and, by
//!    the determinism contract (single accumulator per element, fixed add
//!    order, no fma contraction), bitwise.
//! 2. The fused quantizer hot path (`GradQuantizer::apply_into`) is
//!    bitwise identical to the allocating `apply`, draws the same RNG
//!    stream, honors the NaN poison contract, and reuses its scratch
//!    safely across changing shapes.
//! 3. The integer kernels (`gemm_i8`, `gemm_i8_at_b`, DESIGN.md §5.1)
//!    match their naive integer references bitwise over random shapes
//!    and scale arities, match the dequantize-then-f32-GEMM path bitwise
//!    under power-of-two scales, and track an f64 reference within a
//!    stated ULP band for arbitrary scales.
//! 4. The blocked native executor reproduces the per-sample reference
//!    executor bitwise for every artifact variant and step kind, on the
//!    default geometry and on a deliberately tile-unfriendly one. The
//!    unquantized variants run at bits = 0, pinning the "bits=0 train
//!    steps stay bitwise identical pre/post rewrite" requirement.

use statquant::quant::{FusedScratch, GradQuantizer, Mat};
use statquant::runtime::kernels::{self, Init};
use statquant::runtime::{native, ExecutorBackend, HostTensor, MlpSpec, NativeExecutor, StepKind};
use statquant::util::proptest::{check, prop_assert, Gen};
use statquant::util::rng::Pcg32;

// ---------------------------------------------------------------------------
// 1. Blocked kernels vs naive reference
// ---------------------------------------------------------------------------

/// Draw a dimension that stresses the tiling edges: empty, singleton, or
/// a small non-multiple-of-`MR` size.
fn small_dim(g: &mut Gen) -> usize {
    match g.usize(0..=2) {
        0 => 0,
        1 => 1,
        _ => g.usize(2..=9),
    }
}

/// Like [`small_dim`] but occasionally straddling the `KC` = 128 k-tile
/// boundary, so the outer K loop takes more than one trip.
fn k_dim(g: &mut Gen) -> usize {
    if g.bool(0.3) {
        g.usize(kernels::KC - 3..=kernels::KC + 9)
    } else {
        small_dim(g)
    }
}

/// Relative error against the reference value (absolute below 1.0).
fn rel_err(got: f32, want: f32) -> f32 {
    if got == want {
        0.0
    } else {
        (got - want).abs() / want.abs().max(1.0)
    }
}

fn compare_kernel(got: &[f32], want: &[f32], what: &str) -> Result<(), String> {
    for (i, (&x, &w)) in got.iter().zip(want).enumerate() {
        // the satellite tolerance band…
        if rel_err(x, w) > 1e-5 {
            return Err(format!("{what}: elem {i} off by > 1e-5 rel: {x} vs {w}"));
        }
        // …and the stronger determinism contract (DESIGN.md §5)
        if x.to_bits() != w.to_bits() {
            return Err(format!("{what}: elem {i} not bitwise: {x} vs {w}"));
        }
    }
    Ok(())
}

#[test]
fn prop_blocked_gemm_matches_naive() {
    check(80, |g| {
        let (m, n, k) = (small_dim(g), small_dim(g), k_dim(g));
        let a = g.vec_normal(m * k, 1.0);
        let b = g.vec_normal(k * n, 1.0);
        let bias = g.vec_normal(n, 0.5);
        let with_bias = g.bool(0.5);
        let mut c_blk = vec![f32::NAN; m * n];
        let mut c_ref = vec![f32::NAN; m * n];
        if with_bias {
            kernels::gemm(&mut c_blk, Init::Bias(&bias), &a, &b, m, k, n);
            kernels::naive::gemm(&mut c_ref, Init::Bias(&bias), &a, &b, m, k, n);
        } else {
            kernels::gemm(&mut c_blk, Init::Zero, &a, &b, m, k, n);
            kernels::naive::gemm(&mut c_ref, Init::Zero, &a, &b, m, k, n);
        }
        compare_kernel(&c_blk, &c_ref, &format!("gemm {m}x{k}x{n} bias={with_bias}"))
    });
}

#[test]
fn prop_blocked_gemm_at_b_matches_naive() {
    check(80, |g| {
        // m is the batch (reduction) axis here — let it get large enough
        // to exercise both the 4-sample micro-kernel and its remainder.
        let m = match g.usize(0..=2) {
            0 => small_dim(g),
            1 => g.usize(10..=30),
            _ => g.usize(63..=67),
        };
        let (k, n) = (small_dim(g), small_dim(g));
        let a = g.vec_normal(m * k, 1.0);
        let b = g.vec_normal(m * n, 1.0);
        let mut c_blk = vec![f32::NAN; k * n];
        let mut c_ref = vec![f32::NAN; k * n];
        kernels::gemm_at_b(&mut c_blk, Init::Zero, &a, &b, m, k, n);
        kernels::naive::gemm_at_b(&mut c_ref, Init::Zero, &a, &b, m, k, n);
        compare_kernel(&c_blk, &c_ref, &format!("gemm_at_b {m}x{k}x{n}"))
    });
}

// ---------------------------------------------------------------------------
// 2. Fused quantizer path vs allocating path
// ---------------------------------------------------------------------------

const QUANTIZERS: [GradQuantizer; 5] = [
    GradQuantizer::Ptq,
    GradQuantizer::Psq,
    GradQuantizer::Bhq,
    GradQuantizer::Fp8,
    GradQuantizer::Bfp,
];

fn random_gradient(g: &mut Gen, n: usize, d: usize) -> Mat {
    let mut m = Mat::zeros(n, d);
    for i in 0..n {
        let scale = if i == 0 && g.bool(0.5) { 10.0 } else { g.f32(0.001..2.0) };
        for v in m.row_mut(i) {
            *v = g.normal() * scale;
        }
    }
    m
}

#[test]
fn prop_fused_apply_into_matches_apply_bitwise() {
    check(40, |g| {
        let n = g.usize(1..=12);
        let d = g.usize(1..=16);
        let mut x = random_gradient(g, n, d);
        if g.bool(0.25) {
            // poison one element: PTQ/BHQ poison the whole tensor, PSQ
            // just that row — either way the two paths must agree.
            let i = g.usize(0..=n - 1);
            let j = g.usize(0..=d - 1);
            x.row_mut(i)[j] = f32::NAN;
        }
        let bits = g.usize(1..=8) as f32;
        let stream = g.usize(0..=1_000_000) as u64;
        let mut scratch = FusedScratch::default();
        // deliberately stale shape: apply_into must resize, not assume
        let mut out = Mat::zeros(1, 1);
        for q in QUANTIZERS {
            let mut ra = Pcg32::new(stream, 11);
            let mut rb = Pcg32::new(stream, 11);
            let want = q.apply(&x, bits, &mut ra);
            q.apply_into(&x, bits, &mut rb, &mut scratch, &mut out);
            prop_assert(
                (out.rows, out.cols) == (want.rows, want.cols),
                format!("{q:?}: fused shape {}x{}", out.rows, out.cols),
            )?;
            for (i, (a, b)) in out.data.iter().zip(&want.data).enumerate() {
                if a.to_bits() != b.to_bits() {
                    return Err(format!("{q:?} bits={bits}: elem {i} not bitwise: {a} vs {b}"));
                }
            }
            prop_assert(
                ra.uniform() == rb.uniform(),
                format!("{q:?}: RNG streams diverged between apply and apply_into"),
            )?;
        }
        Ok(())
    });
}

/// The same scratch and output buffer must serve back-to-back calls with
/// different shapes (the data-parallel engine re-enters the executor with
/// varying batch geometry).
#[test]
fn fused_scratch_is_safe_across_shape_changes() {
    let mut scratch = FusedScratch::default();
    let mut out = Mat::zeros(1, 1);
    let mut gen_rng = Pcg32::new(0x5C, 0);
    for (n, d) in [(8usize, 16usize), (3, 5), (12, 4), (1, 1), (6, 33)] {
        let mut x = Mat::zeros(n, d);
        for v in &mut x.data {
            *v = gen_rng.normal();
        }
        for q in QUANTIZERS {
            let mut ra = Pcg32::new(77, 8);
            let mut rb = Pcg32::new(77, 8);
            let want = q.apply(&x, 4.0, &mut ra);
            q.apply_into(&x, 4.0, &mut rb, &mut scratch, &mut out);
            assert_eq!(
                (out.rows, out.cols),
                (want.rows, want.cols),
                "{q:?} {n}x{d} shape"
            );
            for (i, (a, b)) in out.data.iter().zip(&want.data).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{q:?} {n}x{d} elem {i}: {a} vs {b}");
            }
        }
    }
}

// ---------------------------------------------------------------------------
// 3. Integer-code kernels (ISSUE 10): blocked vs naive, and vs dequant-f32
// ---------------------------------------------------------------------------

fn rand_codes(g: &mut Gen, n: usize) -> Vec<i8> {
    (0..n).map(|_| (g.usize(0..=255) as i32 - 128) as i8).collect()
}

/// Blocked `gemm_i8` must match the naive integer reference *bitwise*
/// over random shapes (K = 0, M = 1, K straddling the tile) and both
/// scale arities: i32 accumulation is associative, and the epilogue
/// fold is literally shared code.
#[test]
fn prop_blocked_gemm_i8_matches_naive_bitwise() {
    check(80, |g| {
        let (m, n, k) = (small_dim(g), small_dim(g), k_dim(g));
        let a = rand_codes(g, m * k);
        let bt = rand_codes(g, n * k);
        let scale = |g: &mut Gen, len: usize| -> (Vec<f32>, Vec<f32>) {
            let inv: Vec<f32> = (0..len).map(|_| g.f32(0.001..0.1)).collect();
            let zero: Vec<f32> = (0..len).map(|_| g.f32(-1.0..1.0)).collect();
            (inv, zero)
        };
        let (inv_a, zero_a) = scale(g, if g.bool(0.5) { 1 } else { m.max(1) });
        let (inv_b, zero_b) = scale(g, if g.bool(0.5) { 1 } else { n.max(1) });
        let bias = g.vec_normal(n, 0.5);
        let init = if g.bool(0.5) { Init::Bias(&bias) } else { Init::Zero };
        let mut ws = kernels::IntGemmScratch::default();
        let mut c_blk = vec![f32::NAN; m * n];
        let mut c_ref = vec![f32::NAN; m * n];
        kernels::gemm_i8(
            &mut c_blk, init, &a, &inv_a, &zero_a, &bt, &inv_b, &zero_b, m, n, k, &mut ws,
        );
        kernels::naive::gemm_i8(
            &mut c_ref, init, &a, &inv_a, &zero_a, &bt, &inv_b, &zero_b, m, n, k,
        );
        compare_kernel(&c_blk, &c_ref, &format!("gemm_i8 {m}x{n}x{k}"))
    });
}

#[test]
fn prop_blocked_gemm_i8_at_b_matches_naive_bitwise() {
    check(80, |g| {
        let m = match g.usize(0..=2) {
            0 => small_dim(g),
            1 => g.usize(10..=30),
            _ => g.usize(kernels::KC - 2..=kernels::KC + 5),
        };
        let (k, n) = (small_dim(g), small_dim(g));
        let a = rand_codes(g, m * k);
        let b = rand_codes(g, m * n);
        let (inv_a, zero_a) = (vec![g.f32(0.001..0.1)], vec![g.f32(-1.0..1.0)]);
        let (inv_b, zero_b) = (vec![g.f32(0.001..0.1)], vec![g.f32(-1.0..1.0)]);
        let mut ws = kernels::IntGemmScratch::default();
        let mut c_blk = vec![f32::NAN; k * n];
        let mut c_ref = vec![f32::NAN; k * n];
        kernels::gemm_i8_at_b(
            &mut c_blk, Init::Zero, &a, &inv_a, &zero_a, &b, &inv_b, &zero_b, m, k, n, &mut ws,
        );
        kernels::naive::gemm_i8_at_b(
            &mut c_ref, Init::Zero, &a, &inv_a, &zero_a, &b, &inv_b, &zero_b, m, k, n,
        );
        compare_kernel(&c_blk, &c_ref, &format!("gemm_i8_at_b {m}x{k}x{n}"))
    });
}

/// With power-of-two scales, small K, and full-range codes, every value
/// in both the integer epilogue and the dequantize-then-f32-GEMM path
/// is exactly representable — so the int path must equal the f32 path
/// *bitwise*. This pins the epilogue algebra to the simulate semantics.
#[test]
fn gemm_i8_po2_scales_match_dequant_f32_gemm_bitwise() {
    let (m, n, k) = (5usize, 6usize, 12usize);
    let mut rng = Pcg32::new(0x1D8, 7);
    let code = |rng: &mut Pcg32| (rng.below(256) as i32 - 128) as i8;
    let a: Vec<i8> = (0..m * k).map(|_| code(&mut rng)).collect();
    let bt: Vec<i8> = (0..n * k).map(|_| code(&mut rng)).collect();
    // per-row po2 scales on A (the PSQ axis), per-tensor po2 on B
    let inv_a: Vec<f32> = (0..m).map(|i| if i % 2 == 0 { 0.0078125 } else { 0.03125 }).collect();
    let zero_a: Vec<f32> = (0..m).map(|i| if i % 2 == 0 { 1.0 } else { -0.25 }).collect();
    let (inv_b, zero_b) = (vec![0.015625f32], vec![0.5f32]);

    let mut c_int = vec![f32::NAN; m * n];
    let mut ws = kernels::IntGemmScratch::default();
    kernels::gemm_i8(
        &mut c_int, Init::Zero, &a, &inv_a, &zero_a, &bt, &inv_b, &zero_b, m, n, k, &mut ws,
    );

    // dequantize and run the f32 kernel (B laid out k x n for `gemm`)
    let af: Vec<f32> = (0..m * k)
        .map(|idx| f32::from(a[idx]) * inv_a[idx / k] + zero_a[idx / k])
        .collect();
    let mut bf = vec![0.0f32; k * n];
    for j in 0..n {
        for kk in 0..k {
            bf[kk * n + j] = f32::from(bt[j * k + kk]) * inv_b[0] + zero_b[0];
        }
    }
    let mut c_f32 = vec![f32::NAN; m * n];
    kernels::gemm(&mut c_f32, Init::Zero, &af, &bf, m, k, n);
    for (i, (x, y)) in c_int.iter().zip(&c_f32).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "elem {i}: int {x} vs f32 {y}");
    }
}

/// With arbitrary scales the two formulations differ only by rounding:
/// the int path's error against an f64 reference is bounded by a few
/// ULPs of the term magnitudes (stated band: 32 eps of the absolute
/// dequantized dot plus folded terms).
#[test]
fn prop_gemm_i8_tracks_f64_reference_within_ulp_band() {
    check(60, |g| {
        let (m, n) = (g.usize(1..=6), g.usize(1..=6));
        let k = g.usize(1..=40);
        let a = rand_codes(g, m * k);
        let bt = rand_codes(g, n * k);
        let inv_a = vec![g.f32(0.0001..0.2)];
        let zero_a = vec![g.f32(-2.0..2.0)];
        let inv_b = vec![g.f32(0.0001..0.2)];
        let zero_b = vec![g.f32(-2.0..2.0)];
        let mut c_int = vec![f32::NAN; m * n];
        let mut ws = kernels::IntGemmScratch::default();
        kernels::gemm_i8(
            &mut c_int, Init::Zero, &a, &inv_a, &zero_a, &bt, &inv_b, &zero_b, m, n, k, &mut ws,
        );
        for i in 0..m {
            for j in 0..n {
                let mut want = 0.0f64;
                let mut mag = 0.0f64;
                for kk in 0..k {
                    let av = f64::from(a[i * k + kk]) * f64::from(inv_a[0]) + f64::from(zero_a[0]);
                    let bv = f64::from(bt[j * k + kk]) * f64::from(inv_b[0]) + f64::from(zero_b[0]);
                    want += av * bv;
                    mag += (av * bv).abs();
                }
                let got = f64::from(c_int[i * n + j]);
                let tol = 32.0 * f64::from(f32::EPSILON) * (mag + 1.0);
                prop_assert(
                    (got - want).abs() <= tol,
                    format!("({i},{j}) k={k}: int {got} vs f64 {want}, tol {tol}"),
                )?;
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// 4. Blocked executor vs per-sample reference executor
// ---------------------------------------------------------------------------

fn exec_inputs(
    spec: &MlpSpec,
    step: StepKind,
    params: &[f32],
    x: &[f32],
    y: &[i32],
    bits: f32,
) -> Vec<HostTensor> {
    let p = || HostTensor::F32(params.to_vec());
    let xs = || HostTensor::F32(x.to_vec());
    let ys = || HostTensor::I32(y.to_vec());
    let scalar = |v: f32| HostTensor::F32(vec![v]);
    match step {
        StepKind::Train => vec![
            p(),
            HostTensor::F32(vec![0.01; spec.n_params()]),
            xs(),
            ys(),
            scalar(3.0),
            scalar(0.05),
            scalar(bits),
        ],
        StepKind::Probe => vec![p(), xs(), ys(), scalar(3.0), scalar(bits)],
        StepKind::Eval => vec![p(), xs(), ys()],
        StepKind::ActGrad => vec![p(), xs(), ys(), scalar(3.0)],
    }
}

#[test]
fn executor_blocked_matches_reference_bitwise_for_all_variants_and_steps() {
    // default geometry + one that divides evenly by no tile size
    let odd = MlpSpec {
        in_dim: 13,
        hidden: 7,
        classes: 5,
        batch: 9,
        seed: 0xA11,
    };
    let blocked = NativeExecutor::default();
    let reference = NativeExecutor::reference();
    for spec in [MlpSpec::default(), odd] {
        let params = native::init_params(&spec);
        let mut rng = Pcg32::new(0x9A17, spec.batch as u64);
        let x: Vec<f32> = (0..spec.batch * spec.in_dim).map(|_| rng.normal()).collect();
        let y: Vec<i32> = (0..spec.batch)
            .map(|_| rng.below(spec.classes as u32) as i32)
            .collect();
        for variant in native::VARIANTS {
            // bits = 0 on the unquantized variants pins the "bits=0 train
            // steps stay bitwise identical" acceptance; FQT variants get
            // a live quantizer at 4 bits.
            let bits = if matches!(variant, "exact" | "qat") { 0.0 } else { 4.0 };
            for step in [
                StepKind::Train,
                StepKind::Probe,
                StepKind::Eval,
                StepKind::ActGrad,
            ] {
                let meta = native::meta_for(&spec, variant, step);
                let inputs = exec_inputs(&spec, step, &params, &x, &y, bits);
                let got = blocked.execute(&meta, &inputs).expect("blocked step");
                let want = reference.execute(&meta, &inputs).expect("reference step");
                let tag = format!("{variant}/{} b{}", step.name(), spec.batch);
                assert_eq!(got.len(), want.len(), "{tag}: output arity");
                for (o, (gt, wt)) in got.iter().zip(&want).enumerate() {
                    let gv = gt.as_f32().expect("f32 output");
                    let wv = wt.as_f32().expect("f32 output");
                    assert_eq!(gv.len(), wv.len(), "{tag}: output {o} length");
                    for (i, (a, b)) in gv.iter().zip(wv).enumerate() {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "{tag}: output {o} elem {i}: {a} vs {b}"
                        );
                    }
                }
            }
        }
    }
}
