//! Golden-parity harness for the blocked kernel layer (DESIGN.md §5).
//!
//! Three layers of checks, bottom-up:
//!
//! 1. Blocked GEMM / GEMM-transpose match the retained naive reference
//!    within 1e-5 relative over random M/N/K — including K = 0, M = 1,
//!    non-multiple-of-tile sizes and K straddling the `KC` tile — and, by
//!    the determinism contract (single accumulator per element, fixed add
//!    order, no fma contraction), bitwise.
//! 2. The fused quantizer hot path (`GradQuantizer::apply_into`) is
//!    bitwise identical to the allocating `apply`, draws the same RNG
//!    stream, honors the NaN poison contract, and reuses its scratch
//!    safely across changing shapes.
//! 3. The blocked native executor reproduces the per-sample reference
//!    executor bitwise for every artifact variant and step kind, on the
//!    default geometry and on a deliberately tile-unfriendly one. The
//!    unquantized variants run at bits = 0, pinning the "bits=0 train
//!    steps stay bitwise identical pre/post rewrite" requirement.

use statquant::quant::{FusedScratch, GradQuantizer, Mat};
use statquant::runtime::kernels::{self, Init};
use statquant::runtime::{native, ExecutorBackend, HostTensor, MlpSpec, NativeExecutor, StepKind};
use statquant::util::proptest::{check, prop_assert, Gen};
use statquant::util::rng::Pcg32;

// ---------------------------------------------------------------------------
// 1. Blocked kernels vs naive reference
// ---------------------------------------------------------------------------

/// Draw a dimension that stresses the tiling edges: empty, singleton, or
/// a small non-multiple-of-`MR` size.
fn small_dim(g: &mut Gen) -> usize {
    match g.usize(0..=2) {
        0 => 0,
        1 => 1,
        _ => g.usize(2..=9),
    }
}

/// Like [`small_dim`] but occasionally straddling the `KC` = 128 k-tile
/// boundary, so the outer K loop takes more than one trip.
fn k_dim(g: &mut Gen) -> usize {
    if g.bool(0.3) {
        g.usize(kernels::KC - 3..=kernels::KC + 9)
    } else {
        small_dim(g)
    }
}

/// Relative error against the reference value (absolute below 1.0).
fn rel_err(got: f32, want: f32) -> f32 {
    if got == want {
        0.0
    } else {
        (got - want).abs() / want.abs().max(1.0)
    }
}

fn compare_kernel(got: &[f32], want: &[f32], what: &str) -> Result<(), String> {
    for (i, (&x, &w)) in got.iter().zip(want).enumerate() {
        // the satellite tolerance band…
        if rel_err(x, w) > 1e-5 {
            return Err(format!("{what}: elem {i} off by > 1e-5 rel: {x} vs {w}"));
        }
        // …and the stronger determinism contract (DESIGN.md §5)
        if x.to_bits() != w.to_bits() {
            return Err(format!("{what}: elem {i} not bitwise: {x} vs {w}"));
        }
    }
    Ok(())
}

#[test]
fn prop_blocked_gemm_matches_naive() {
    check(80, |g| {
        let (m, n, k) = (small_dim(g), small_dim(g), k_dim(g));
        let a = g.vec_normal(m * k, 1.0);
        let b = g.vec_normal(k * n, 1.0);
        let bias = g.vec_normal(n, 0.5);
        let with_bias = g.bool(0.5);
        let mut c_blk = vec![f32::NAN; m * n];
        let mut c_ref = vec![f32::NAN; m * n];
        if with_bias {
            kernels::gemm(&mut c_blk, Init::Bias(&bias), &a, &b, m, k, n);
            kernels::naive::gemm(&mut c_ref, Init::Bias(&bias), &a, &b, m, k, n);
        } else {
            kernels::gemm(&mut c_blk, Init::Zero, &a, &b, m, k, n);
            kernels::naive::gemm(&mut c_ref, Init::Zero, &a, &b, m, k, n);
        }
        compare_kernel(&c_blk, &c_ref, &format!("gemm {m}x{k}x{n} bias={with_bias}"))
    });
}

#[test]
fn prop_blocked_gemm_at_b_matches_naive() {
    check(80, |g| {
        // m is the batch (reduction) axis here — let it get large enough
        // to exercise both the 4-sample micro-kernel and its remainder.
        let m = match g.usize(0..=2) {
            0 => small_dim(g),
            1 => g.usize(10..=30),
            _ => g.usize(63..=67),
        };
        let (k, n) = (small_dim(g), small_dim(g));
        let a = g.vec_normal(m * k, 1.0);
        let b = g.vec_normal(m * n, 1.0);
        let mut c_blk = vec![f32::NAN; k * n];
        let mut c_ref = vec![f32::NAN; k * n];
        kernels::gemm_at_b(&mut c_blk, Init::Zero, &a, &b, m, k, n);
        kernels::naive::gemm_at_b(&mut c_ref, Init::Zero, &a, &b, m, k, n);
        compare_kernel(&c_blk, &c_ref, &format!("gemm_at_b {m}x{k}x{n}"))
    });
}

// ---------------------------------------------------------------------------
// 2. Fused quantizer path vs allocating path
// ---------------------------------------------------------------------------

const QUANTIZERS: [GradQuantizer; 5] = [
    GradQuantizer::Ptq,
    GradQuantizer::Psq,
    GradQuantizer::Bhq,
    GradQuantizer::Fp8,
    GradQuantizer::Bfp,
];

fn random_gradient(g: &mut Gen, n: usize, d: usize) -> Mat {
    let mut m = Mat::zeros(n, d);
    for i in 0..n {
        let scale = if i == 0 && g.bool(0.5) { 10.0 } else { g.f32(0.001..2.0) };
        for v in m.row_mut(i) {
            *v = g.normal() * scale;
        }
    }
    m
}

#[test]
fn prop_fused_apply_into_matches_apply_bitwise() {
    check(40, |g| {
        let n = g.usize(1..=12);
        let d = g.usize(1..=16);
        let mut x = random_gradient(g, n, d);
        if g.bool(0.25) {
            // poison one element: PTQ/BHQ poison the whole tensor, PSQ
            // just that row — either way the two paths must agree.
            let i = g.usize(0..=n - 1);
            let j = g.usize(0..=d - 1);
            x.row_mut(i)[j] = f32::NAN;
        }
        let bits = g.usize(1..=8) as f32;
        let stream = g.usize(0..=1_000_000) as u64;
        let mut scratch = FusedScratch::default();
        // deliberately stale shape: apply_into must resize, not assume
        let mut out = Mat::zeros(1, 1);
        for q in QUANTIZERS {
            let mut ra = Pcg32::new(stream, 11);
            let mut rb = Pcg32::new(stream, 11);
            let want = q.apply(&x, bits, &mut ra);
            q.apply_into(&x, bits, &mut rb, &mut scratch, &mut out);
            prop_assert(
                (out.rows, out.cols) == (want.rows, want.cols),
                format!("{q:?}: fused shape {}x{}", out.rows, out.cols),
            )?;
            for (i, (a, b)) in out.data.iter().zip(&want.data).enumerate() {
                if a.to_bits() != b.to_bits() {
                    return Err(format!("{q:?} bits={bits}: elem {i} not bitwise: {a} vs {b}"));
                }
            }
            prop_assert(
                ra.uniform() == rb.uniform(),
                format!("{q:?}: RNG streams diverged between apply and apply_into"),
            )?;
        }
        Ok(())
    });
}

/// The same scratch and output buffer must serve back-to-back calls with
/// different shapes (the data-parallel engine re-enters the executor with
/// varying batch geometry).
#[test]
fn fused_scratch_is_safe_across_shape_changes() {
    let mut scratch = FusedScratch::default();
    let mut out = Mat::zeros(1, 1);
    let mut gen_rng = Pcg32::new(0x5C, 0);
    for (n, d) in [(8usize, 16usize), (3, 5), (12, 4), (1, 1), (6, 33)] {
        let mut x = Mat::zeros(n, d);
        for v in &mut x.data {
            *v = gen_rng.normal();
        }
        for q in QUANTIZERS {
            let mut ra = Pcg32::new(77, 8);
            let mut rb = Pcg32::new(77, 8);
            let want = q.apply(&x, 4.0, &mut ra);
            q.apply_into(&x, 4.0, &mut rb, &mut scratch, &mut out);
            assert_eq!(
                (out.rows, out.cols),
                (want.rows, want.cols),
                "{q:?} {n}x{d} shape"
            );
            for (i, (a, b)) in out.data.iter().zip(&want.data).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{q:?} {n}x{d} elem {i}: {a} vs {b}");
            }
        }
    }
}

// ---------------------------------------------------------------------------
// 3. Blocked executor vs per-sample reference executor
// ---------------------------------------------------------------------------

fn exec_inputs(
    spec: &MlpSpec,
    step: StepKind,
    params: &[f32],
    x: &[f32],
    y: &[i32],
    bits: f32,
) -> Vec<HostTensor> {
    let p = || HostTensor::F32(params.to_vec());
    let xs = || HostTensor::F32(x.to_vec());
    let ys = || HostTensor::I32(y.to_vec());
    let scalar = |v: f32| HostTensor::F32(vec![v]);
    match step {
        StepKind::Train => vec![
            p(),
            HostTensor::F32(vec![0.01; spec.n_params()]),
            xs(),
            ys(),
            scalar(3.0),
            scalar(0.05),
            scalar(bits),
        ],
        StepKind::Probe => vec![p(), xs(), ys(), scalar(3.0), scalar(bits)],
        StepKind::Eval => vec![p(), xs(), ys()],
        StepKind::ActGrad => vec![p(), xs(), ys(), scalar(3.0)],
    }
}

#[test]
fn executor_blocked_matches_reference_bitwise_for_all_variants_and_steps() {
    // default geometry + one that divides evenly by no tile size
    let odd = MlpSpec {
        in_dim: 13,
        hidden: 7,
        classes: 5,
        batch: 9,
        seed: 0xA11,
    };
    let blocked = NativeExecutor::default();
    let reference = NativeExecutor::reference();
    for spec in [MlpSpec::default(), odd] {
        let params = native::init_params(&spec);
        let mut rng = Pcg32::new(0x9A17, spec.batch as u64);
        let x: Vec<f32> = (0..spec.batch * spec.in_dim).map(|_| rng.normal()).collect();
        let y: Vec<i32> = (0..spec.batch)
            .map(|_| rng.below(spec.classes as u32) as i32)
            .collect();
        for variant in native::VARIANTS {
            // bits = 0 on the unquantized variants pins the "bits=0 train
            // steps stay bitwise identical" acceptance; FQT variants get
            // a live quantizer at 4 bits.
            let bits = if matches!(variant, "exact" | "qat") { 0.0 } else { 4.0 };
            for step in [
                StepKind::Train,
                StepKind::Probe,
                StepKind::Eval,
                StepKind::ActGrad,
            ] {
                let meta = native::meta_for(&spec, variant, step);
                let inputs = exec_inputs(&spec, step, &params, &x, &y, bits);
                let got = blocked.execute(&meta, &inputs).expect("blocked step");
                let want = reference.execute(&meta, &inputs).expect("reference step");
                let tag = format!("{variant}/{} b{}", step.name(), spec.batch);
                assert_eq!(got.len(), want.len(), "{tag}: output arity");
                for (o, (gt, wt)) in got.iter().zip(&want).enumerate() {
                    let gv = gt.as_f32().expect("f32 output");
                    let wv = wt.as_f32().expect("f32 output");
                    assert_eq!(gv.len(), wv.len(), "{tag}: output {o} length");
                    for (i, (a, b)) in gv.iter().zip(wv).enumerate() {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "{tag}: output {o} elem {i}: {a} vs {b}"
                        );
                    }
                }
            }
        }
    }
}
