//! End-to-end tests for the observability stack: a real native training
//! run must leave a run directory whose obs artifacts (metrics.prom,
//! trace.json, metrics.jsonl, log.jsonl) parse with our own readers and
//! feed the `trace-report` renderer.
//!
//! These tests share process-global obs state (registry, span rings, the
//! enabled flag), so they serialize on a local mutex and never disable
//! obs — the overhead bench covers the disabled path in its own process.

use std::path::{Path, PathBuf};
use std::sync::Mutex;

use statquant::config::TrainConfig;
use statquant::coordinator::Trainer;
use statquant::obs;
use statquant::runtime::{native, MlpSpec, Registry, Runtime};
use statquant::util::json::Json;

static SERIAL: Mutex<()> = Mutex::new(());

fn setup(tag: &str) -> (PathBuf, Registry, Runtime) {
    let dir = std::env::temp_dir().join(format!("sq_obs_e2e_{}_{}", tag, std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    native::write_artifacts(&dir, &MlpSpec::default()).unwrap();
    let reg = Registry::open(&dir).unwrap();
    (dir, reg, Runtime::native())
}

fn base_cfg(artifacts: &Path, variant: &str, steps: u64) -> TrainConfig {
    TrainConfig {
        model: "mlp".into(),
        variant: variant.into(),
        steps,
        lr: 0.05,
        bits: 5.0,
        eval_every: 10,
        eval_batches: 2,
        seed: 3,
        artifacts_dir: artifacts.display().to_string(),
        out_dir: artifacts.join("runs").display().to_string(),
        ..TrainConfig::default()
    }
}

fn read_jsonl_lines(path: &Path) -> Vec<Json> {
    std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()))
        .lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| Json::parse(l).unwrap_or_else(|e| panic!("bad jsonl line {l:?}: {e}")))
        .collect()
}

#[test]
fn training_emits_parseable_obs_artifacts() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    obs::set_enabled(true);
    let (dir, reg, rt) = setup("artifacts");
    let cfg = base_cfg(&dir, "ptq", 30);
    let run_dir = PathBuf::from(&cfg.out_dir).join(cfg.run_name());
    let mut tr = Trainer::new(&rt, &reg, cfg).unwrap();
    let report = tr.train().unwrap();
    assert!(!report.diverged);

    // Prometheus text round-trips our own parser and carries the
    // counters the trainer, quantizers, and executor must have bumped.
    let prom = std::fs::read_to_string(run_dir.join("metrics.prom")).unwrap();
    let samples = obs::registry::parse_prometheus(&prom);
    assert!(
        samples.get("train_steps_total").copied().unwrap_or(0.0) >= 30.0,
        "train_steps_total missing or too small in:\n{prom}"
    );
    assert!(
        samples
            .get("quant_values_total{quantizer=\"ptq\"}")
            .copied()
            .unwrap_or(0.0)
            > 0.0,
        "ptq telemetry never fired"
    );
    assert!(
        samples.keys().any(|k| k.starts_with("executor_dispatch_total")),
        "no executor dispatch counters"
    );

    // Chrome trace parses and aggregates; every instrumented phase of
    // the hot loop shows up.
    let trace = Json::parse(&std::fs::read_to_string(run_dir.join("trace.json")).unwrap()).unwrap();
    let (phases, wall_us) = obs::report::phase_breakdown(&trace).unwrap();
    assert!(wall_us > 0.0);
    for want in ["train/step", "train/data", "train/dispatch", "exec/train", "train/eval"] {
        assert!(
            phases.iter().any(|p| p.name == want && p.count > 0),
            "phase {want} missing from trace; got {:?}",
            phases.iter().map(|p| p.name.as_str()).collect::<Vec<_>>()
        );
    }

    // log.jsonl eval records carry the quantizer-health fields.
    let evals: Vec<Json> = read_jsonl_lines(&run_dir.join("log.jsonl"))
        .into_iter()
        .filter(|j| j.get("eval_loss").is_some())
        .collect();
    assert!(!evals.is_empty(), "no eval records in log.jsonl");
    for e in &evals {
        assert!(e.get("quant_clip_rate").is_some(), "missing quant_clip_rate");
        assert!(e.get("quant_grad_var").is_some(), "missing quant_grad_var");
    }

    // metrics.jsonl holds at least two registry snapshots.
    let snaps = read_jsonl_lines(&run_dir.join("metrics.jsonl"));
    assert!(snaps.len() >= 2, "expected >= 2 snapshots, got {}", snaps.len());
    assert!(snaps.iter().all(|s| s.get("counters").is_some()));

    // And the whole directory renders as a markdown report.
    let md = obs::report::render_run_report(&run_dir).unwrap();
    assert!(md.contains("Per-phase time breakdown"), "{md}");
    assert!(md.contains("Quantizer health"), "{md}");
    assert!(md.contains("train/step"), "{md}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn divergence_is_recorded_in_report_and_jsonl() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    obs::set_enabled(true);
    let (dir, reg, rt) = setup("diverge");
    let mut cfg = base_cfg(&dir, "qat", 20);
    cfg.lr = 1e8;
    cfg.schedule = "constant".into();
    cfg.warmup_frac = 0.0;
    let run_dir = PathBuf::from(&cfg.out_dir).join(cfg.run_name());
    let mut tr = Trainer::new(&rt, &reg, cfg).unwrap();
    let report = tr.train().unwrap();

    assert!(report.diverged, "lr=1e8 should diverge");
    let at = report.diverged_at_step.expect("diverged_at_step set");
    assert!(at < 20, "diverged_at_step {at} out of range");

    let diverged_lines: Vec<Json> = read_jsonl_lines(&run_dir.join("log.jsonl"))
        .into_iter()
        .filter(|j| j.get("diverged_at_step").is_some())
        .collect();
    assert_eq!(diverged_lines.len(), 1, "expected exactly one divergence record");
    assert_eq!(
        diverged_lines[0].get("diverged_at_step").and_then(Json::as_f64),
        Some(at as f64)
    );
    std::fs::remove_dir_all(&dir).ok();
}
