//! Property-based tests over the native quantizer stack and substrates
//! (DESIGN.md §8), using the in-repo proptest-lite harness.

use statquant::config::TrainConfig;
use statquant::coordinator::Schedule;
use statquant::quant::{bfp, bhq, fp8, nbins, psq, ptq, GradQuantizer, Mat};
use statquant::stats::{Histogram, VectorWelford, Welford};
use statquant::util::json::Json;
use statquant::util::proptest::{check, prop_assert, Gen};
use statquant::util::rng::Pcg32;
use statquant::util::toml;

fn random_matrix(g: &mut Gen, max_n: usize, max_d: usize) -> Mat {
    let n = g.usize(1..=max_n);
    let d = g.usize(1..=max_d);
    let outlier = g.bool(0.5);
    let mut m = Mat::zeros(n, d);
    for i in 0..n {
        let scale = if outlier && i == 0 { 10.0 } else { g.f32(0.001..2.0) };
        for v in m.row_mut(i) {
            *v = g.normal() * scale;
        }
    }
    m
}

// ---------------------------------------------------------------------------
// Quantizer invariants
// ---------------------------------------------------------------------------

/// Every quantizer's reconstruction error is bounded elementwise: for the
/// affine quantizers, |deq - x| <= that row's bin size (SR moves at most
/// one bin; zero-point shift is exact).
#[test]
fn prop_reconstruction_error_bounded() {
    check(60, |g| {
        let x = random_matrix(g, 24, 48);
        let bits = g.usize(2..=8) as f32;
        let nb = nbins(bits);
        let q = ptq::quantize(&x, nb, g.rng());
        for (i, (&d, &v)) in q.deq.data.iter().zip(&x.data).enumerate() {
            let bin = q.row_bin_size[i / x.cols];
            if (d - v).abs() > bin * 1.01 + 1e-6 {
                return Err(format!("ptq elem {i}: |{d}-{v}| > bin {bin}"));
            }
        }
        let q = psq::quantize(&x, nb, g.rng());
        for (i, (&d, &v)) in q.deq.data.iter().zip(&x.data).enumerate() {
            let bin = q.row_bin_size[i / x.cols];
            if (d - v).abs() > bin * 1.01 + 1e-6 {
                return Err(format!("psq elem {i}: |{d}-{v}| > bin {bin}"));
            }
        }
        Ok(())
    });
}

/// PSQ's variance bound is never above PTQ's (§4.1: R(X) = max_i R(x_i)).
#[test]
fn prop_psq_bound_le_ptq_bound() {
    check(80, |g| {
        let x = random_matrix(g, 16, 32);
        let nb = nbins(g.usize(2..=8) as f32);
        prop_assert(
            psq::variance_bound(&x, nb) <= ptq::variance_bound(&x, nb) * (1.0 + 1e-9),
            "psq bound > ptq bound",
        )
    });
}

/// BHQ plan is always a partition with sorted-leader structure, for any
/// input (including degenerate all-zero and constant matrices).
#[test]
fn prop_bhq_plan_partition() {
    check(80, |g| {
        let x = if g.bool(0.1) {
            Mat::zeros(g.usize(1..=16), g.usize(1..=8)) // degenerate
        } else {
            random_matrix(g, 32, 16)
        };
        let plan = bhq::build_plan(&x);
        let mut seen = vec![false; x.rows];
        for grp in &plan.groups {
            if grp.rows.is_empty() {
                return Err("empty group".into());
            }
            for &r in &grp.rows {
                if seen[r] {
                    return Err(format!("row {r} twice"));
                }
                seen[r] = true;
            }
            if !(grp.s1.is_finite() && grp.s2.is_finite() && grp.s1 > 0.0 && grp.s2 > 0.0) {
                return Err(format!("bad scales {} {}", grp.s1, grp.s2));
            }
        }
        prop_assert(seen.into_iter().all(|s| s), "rows not covered")
    });
}

/// BHQ round trip at high bitwidth reconstructs tightly (transform is
/// orthogonal, so no amplification) for any structure.
#[test]
fn prop_bhq_high_bits_tight() {
    check(40, |g| {
        let x = random_matrix(g, 16, 24);
        let q = bhq::quantize(&x, nbins(10.0), g.rng());
        let rel = q.deq.sq_err(&x) / x.frob_sq().max(1e-12);
        prop_assert(rel < 1e-2, format!("rel err {rel}"))
    });
}

/// All quantizers preserve shape and produce finite values on any input.
#[test]
fn prop_all_quantizers_finite() {
    check(60, |g| {
        let x = random_matrix(g, 12, 20);
        let bits = g.usize(2..=8) as f32;
        for q in GradQuantizer::ALL {
            let out = q.apply(&x, bits, g.rng());
            if out.rows != x.rows || out.cols != x.cols {
                return Err(format!("{q:?} changed shape"));
            }
            if !out.data.iter().all(|v| v.is_finite()) {
                return Err(format!("{q:?} produced non-finite values"));
            }
        }
        Ok(())
    });
}

/// FP8 saturates: outputs never exceed the max-normal after unscaling.
#[test]
fn prop_fp8_saturation() {
    check(40, |g| {
        let x = random_matrix(g, 8, 16);
        let absmax = x.data.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        let out = fp8::quantize(&x, g.rng());
        let omax = out.data.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        prop_assert(
            omax <= absmax * 1.001 + 1e-6,
            format!("fp8 overshoot {omax} > {absmax}"),
        )
    });
}

/// BFP with block == cols equals BFP row-at-once; ragged blocks cover all.
#[test]
fn prop_bfp_block_coverage() {
    check(40, |g| {
        let x = random_matrix(g, 6, 40);
        let block = g.usize(1..=48);
        let out = bfp::quantize(&x, nbins(8.0), block, g.rng());
        prop_assert(
            out.data.iter().all(|v| v.is_finite()) && out.cols == x.cols,
            "bfp bad output",
        )
    });
}

// ---------------------------------------------------------------------------
// Substrate invariants
// ---------------------------------------------------------------------------

/// Welford merge == sequential, for random splits.
#[test]
fn prop_welford_merge() {
    check(60, |g| {
        let n = g.usize(2..=200);
        let xs = g.vec_normal(n, 3.0);
        let cut = g.usize(1..=n - 1);
        let mut all = Welford::new();
        let mut a = Welford::new();
        let mut b = Welford::new();
        for (i, &x) in xs.iter().enumerate() {
            all.push(f64::from(x));
            if i < cut {
                a.push(f64::from(x));
            } else {
                b.push(f64::from(x));
            }
        }
        a.merge(&b);
        prop_assert(
            (a.mean() - all.mean()).abs() < 1e-9
                && (a.variance() - all.variance()).abs() < 1e-9,
            "merge mismatch",
        )
    });
}

/// VectorWelford total variance equals the sum of scalar Welfords.
#[test]
fn prop_vector_welford_consistent() {
    check(30, |g| {
        let dim = g.usize(1..=8);
        let n = g.usize(2..=50);
        let mut vw = VectorWelford::new(dim);
        let mut ws: Vec<Welford> = (0..dim).map(|_| Welford::new()).collect();
        for _ in 0..n {
            let xs = g.vec_normal(dim, 1.0);
            vw.push(&xs);
            for (w, &x) in ws.iter_mut().zip(&xs) {
                w.push(f64::from(x));
            }
        }
        let sum: f64 = ws.iter().map(Welford::sample_variance).sum();
        prop_assert(
            (vw.total_variance() - sum).abs() < 1e-9 * sum.max(1.0),
            format!("{} vs {}", vw.total_variance(), sum),
        )
    });
}

/// Histogram conserves mass: total == pushed count for any data/range.
#[test]
fn prop_histogram_mass() {
    check(60, |g| {
        let n = g.usize(1..=300);
        let vals = g.vec_f32(n, -50.0..50.0);
        let h = Histogram::from_values(&vals, g.usize(1..=64));
        prop_assert(h.total() as usize == n, "mass lost")
    });
}

/// LR schedules never produce negative or non-finite rates, and warmup
/// never exceeds the base rate.
#[test]
fn prop_lr_schedules_sane() {
    check(80, |g| {
        let total = g.usize(1..=1000) as u64;
        let warmup = g.usize(0..=total as usize) as u64;
        let base = g.f32(1e-5..10.0) as f64;
        for sched in [Schedule::Cosine, Schedule::Constant, Schedule::Step] {
            for step in 0..total {
                let lr = sched.lr(base, step, total, warmup);
                if !(lr.is_finite() && lr >= 0.0 && lr <= base * 1.0001) {
                    return Err(format!("{sched:?} step {step}: lr {lr}"));
                }
            }
        }
        Ok(())
    });
}

/// JSON roundtrip: any tree we can build serializes and reparses equal.
#[test]
fn prop_json_roundtrip() {
    fn random_json(g: &mut Gen, depth: usize) -> Json {
        match if depth == 0 { g.usize(0..=3) } else { g.usize(0..=5) } {
            0 => Json::Null,
            1 => Json::Bool(g.bool(0.5)),
            2 => Json::Num((g.normal() * 100.0).round().into()),
            3 => Json::Str(
                (0..g.usize(0..=12))
                    .map(|_| char::from(b'a' + (g.usize(0..=25) as u8)))
                    .collect(),
            ),
            4 => Json::Arr((0..g.usize(0..=4)).map(|_| random_json(g, depth - 1)).collect()),
            _ => Json::Obj(
                (0..g.usize(0..=4))
                    .map(|i| (format!("k{i}"), random_json(g, depth - 1)))
                    .collect(),
            ),
        }
    }
    check(100, |g| {
        let j = random_json(g, 3);
        let s = j.to_string_pretty();
        match Json::parse(&s) {
            Ok(j2) => prop_assert(j == j2, format!("roundtrip mismatch: {s}")),
            Err(e) => Err(format!("reparse failed: {e} for {s}")),
        }
    });
}

/// TOML: config overrides parse and round-trip through TrainConfig::set.
#[test]
fn prop_config_set_numeric_fields() {
    check(60, |g| {
        let mut cfg = TrainConfig::default();
        let lr = g.f32(0.0001..2.0) as f64;
        let steps = g.usize(1..=5000);
        let bits = g.usize(2..=8);
        cfg.set(&format!("lr={lr}")).map_err(|e| e.to_string())?;
        cfg.set(&format!("steps={steps}")).map_err(|e| e.to_string())?;
        cfg.set(&format!("bits={bits}")).map_err(|e| e.to_string())?;
        prop_assert(
            (cfg.lr - lr).abs() < 1e-12 && cfg.steps == steps as u64,
            "set mismatch",
        )
    });
}

/// TOML parser: generated simple configs always parse to the same tree.
#[test]
fn prop_toml_parse_generated() {
    check(60, |g| {
        let a = g.usize(0..=100);
        let b = g.f32(-5.0..5.0);
        let text = format!("[s]\na = {a}\nb = {b}\nflag = true\nname = \"x\"\n");
        let j = toml::parse(&text).map_err(|e| e.to_string())?;
        prop_assert(
            j.path("s.a").and_then(Json::as_usize) == Some(a)
                && j.path("s.flag").and_then(Json::as_bool) == Some(true),
            "toml field mismatch",
        )
    });
}

/// Pcg32 `below(n)` is always < n (Lemire rejection).
#[test]
fn prop_pcg_below_in_range() {
    check(100, |g| {
        let n = g.usize(1..=1_000_000) as u32;
        let mut rng = Pcg32::new(g.case, 5);
        for _ in 0..100 {
            if rng.below(n) >= n {
                return Err(format!("below({n}) out of range"));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Ring all-reduce invariants (ISSUE 8)
// ---------------------------------------------------------------------------

/// Segment-quantized ring reduce equals whole-matrix quantize-then-average
/// *in expectation*: averaging many ring reductions (varying the step, so
/// every (step, worker, segment) triple draws fresh SR noise) converges to
/// the true per-element worker mean within a CLT band — for random worker
/// counts, parameter sizes (hence random segment splits), and quantizers.
#[test]
fn prop_ring_reduce_unbiased_over_random_splits() {
    use statquant::coordinator::data_parallel::ring_reduce;
    check(10, |g| {
        let workers = g.usize(2..=6);
        let p = g.usize(8..=96);
        let chunk = g.usize(1..=32);
        let q = GradQuantizer::PAPER[g.usize(0..=GradQuantizer::PAPER.len() - 1)];
        let mut grads = Mat::zeros(workers, p);
        for w in 0..workers {
            let scale = if w == 0 { 5.0 } else { g.f32(0.01..1.0) };
            for v in grads.row_mut(w) {
                *v = g.normal() * scale;
            }
        }
        // true dense fp32 mean across workers
        let mut truth = vec![0.0f64; p];
        for w in 0..workers {
            for (t, &v) in truth.iter_mut().zip(grads.row(w)) {
                *t += f64::from(v) / workers as f64;
            }
        }
        let reps = 600u64;
        let mut sum = vec![0.0f64; p];
        let mut sumsq = vec![0.0f64; p];
        for rep in 0..reps {
            let r = ring_reduce(&grads, q, 3.0, rep, chunk);
            for (j, &v) in r.iter().enumerate() {
                sum[j] += f64::from(v);
                sumsq[j] += f64::from(v) * f64::from(v);
            }
        }
        let kf = reps as f64;
        // rare-bin-flip drift floor (same reasoning as the quantizer
        // unbiasedness tests): a worker whose flip probability for an
        // element is O(1/reps) may flip zero times, leaving up to
        // ~bin/reps of undetectable mean shift with zero empirical SE.
        // Bound the bin by the global range (x2 for BHQ's transformed
        // space); the per-worker 1/W factors sum back out over workers.
        let (lo, hi) = grads.minmax();
        let floor = 12.0 * 2.0 * f64::from(hi - lo) / f64::from(nbins(3.0)) / kf + 1e-7;
        for j in 0..p {
            let mean = sum[j] / kf;
            let var = (sumsq[j] / kf - mean * mean).max(0.0);
            let se = (var / kf).sqrt();
            let dev = (mean - truth[j]).abs();
            if dev > 6.0 * se + floor {
                return Err(format!(
                    "{q:?} W={workers} p={p} chunk={chunk} elem {j}: \
                     |E[ring] - mean| = {dev:.3e} > {:.3e}",
                    6.0 * se + floor
                ));
            }
        }
        Ok(())
    });
}

/// `segment_seed` never collides across random grids of
/// (step, worker, segment) triples — the determinism contract requires
/// every ring payload to draw from a distinct SR stream.
#[test]
fn prop_segment_seed_no_collisions() {
    use statquant::coordinator::data_parallel::segment_seed;
    use std::collections::HashMap;
    check(20, |g| {
        let steps: Vec<u64> = (0..g.usize(2..=12))
            .map(|_| g.usize(0..=1_000_000) as u64)
            .collect();
        let workers = g.usize(1..=16);
        let segments = g.usize(1..=16);
        let mut seen: HashMap<u64, (u64, usize, usize)> = HashMap::new();
        for &s in &steps {
            for w in 0..workers {
                for seg in 0..segments {
                    if let Some(prev) = seen.insert(segment_seed(s, w, seg), (s, w, seg)) {
                        if prev != (s, w, seg) {
                            return Err(format!(
                                "seed collision: {prev:?} vs {:?}",
                                (s, w, seg)
                            ));
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

/// `seg_bounds` is always a contiguous, exhaustive partition of [0, p),
/// with one (possibly empty) segment per worker.
#[test]
fn prop_seg_bounds_partition() {
    use statquant::coordinator::data_parallel::seg_bounds;
    check(100, |g| {
        let p = g.usize(0..=4096);
        let w = g.usize(1..=64);
        let b = seg_bounds(p, w);
        if b.len() != w {
            return Err(format!("{} segments for {w} workers", b.len()));
        }
        let mut cursor = 0usize;
        for &(lo, hi) in &b {
            if lo != cursor || hi < lo {
                return Err(format!("non-contiguous at ({lo},{hi}), cursor {cursor}"));
            }
            cursor = hi;
        }
        prop_assert(cursor == p, format!("covered {cursor} of {p}"))
    });
}

/// Unbiasedness as a property: mean over many draws approaches the input
/// for randomly structured matrices (all paper quantizers).
#[test]
fn prop_quantizers_unbiased_statistical() {
    check(8, |g| {
        let x = random_matrix(g, 8, 12);
        let reps = 400;
        for q in GradQuantizer::PAPER {
            let mut mean = vec![0.0f64; x.len()];
            let mut m2 = vec![0.0f64; x.len()];
            for _ in 0..reps {
                let out = q.apply(&x, 4.0, g.rng());
                for ((m, s), &v) in mean.iter_mut().zip(m2.iter_mut()).zip(&out.data) {
                    *m += f64::from(v) / f64::from(reps);
                    *s += f64::from(v) * f64::from(v) / f64::from(reps);
                }
            }
            // worst-case undetectable drift when frac(t) ~ few/reps: a
            // rare bin-flip may not be sampled at all, shifting the mean
            // by up to ~bin * O(1/reps). Bound bin by the global range/B.
            let (lo, hi) = x.minmax();
            let bin = f64::from(hi - lo) / 15.0;
            for i in 0..x.len() {
                let var = (m2[i] - mean[i] * mean[i]).max(0.0);
                let se = (var / f64::from(reps)).sqrt();
                let diff = (mean[i] - f64::from(x.data[i])).abs();
                if diff > 6.0 * se + 10.0 * bin / f64::from(reps)
                    + 1e-3 * f64::from(x.data[i].abs()) + 1e-5 {
                    return Err(format!(
                        "{q:?} elem {i}: mean {} vs {} (se {se})",
                        mean[i], x.data[i]
                    ));
                }
            }
        }
        Ok(())
    });
}
