//! End-to-end tests for the native executor backend: generate artifacts,
//! run the full Trainer loop, the data-parallel coordinator, and a
//! checkpoint roundtrip — all without XLA/PJRT. These are the tier-1
//! guarantee that `cargo test` exercises the real training path on a
//! clean machine.

use std::path::{Path, PathBuf};

use statquant::config::TrainConfig;
use statquant::coordinator::{make_dataset, Checkpoint, DataParallel, Schedule, Trainer};
use statquant::quant::GradQuantizer;
use statquant::runtime::{native, MlpSpec, Registry, Runtime, StepKind};

/// Fresh artifact dir + registry + native runtime for one test.
fn setup(tag: &str) -> (PathBuf, Registry, Runtime) {
    let dir = std::env::temp_dir().join(format!("sq_native_e2e_{}_{}", tag, std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    native::write_artifacts(&dir, &MlpSpec::default()).unwrap();
    let reg = Registry::open(&dir).unwrap();
    (dir, reg, Runtime::native())
}

fn base_cfg(artifacts: &Path, variant: &str, steps: u64) -> TrainConfig {
    TrainConfig {
        model: "mlp".into(),
        variant: variant.into(),
        steps,
        lr: 0.05,
        bits: 5.0,
        eval_every: steps.max(1),
        eval_batches: 4,
        seed: 7,
        artifacts_dir: artifacts.display().to_string(),
        out_dir: artifacts.join("runs").display().to_string(),
        ..TrainConfig::default()
    }
}

#[test]
fn trainer_converges_on_native_backend() {
    let (dir, reg, rt) = setup("train");
    let mut tr = Trainer::new(&rt, &reg, base_cfg(&dir, "qat", 60)).unwrap();
    let report = tr.train().unwrap();
    assert!(!report.diverged, "training diverged");
    assert_eq!(report.steps, 60);
    let first = report.curve[0].1;
    assert!(
        report.final_train_loss < 0.9 * first,
        "loss did not decrease: {first} -> {}",
        report.final_train_loss
    );
    assert!(report.final_eval_loss.is_finite());
    assert!(report.final_eval_acc > 0.2, "acc {}", report.final_eval_acc);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn training_is_deterministic_given_seed() {
    let (dir, reg, rt) = setup("det");
    let run = |seed: u64| {
        let mut cfg = base_cfg(&dir, "psq", 20);
        cfg.seed = seed;
        let mut tr = Trainer::new(&rt, &reg, cfg).unwrap();
        tr.train().unwrap().params
    };
    let a = run(7);
    let b = run(7);
    let c = run(8);
    assert_eq!(a, b, "same seed must replay bit-for-bit");
    assert_ne!(a, c, "different seed must draw different SR noise");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn quantized_variants_train_without_divergence() {
    let (dir, reg, rt) = setup("variants");
    for variant in ["ptq", "psq", "bhq"] {
        let mut tr = Trainer::new(&rt, &reg, base_cfg(&dir, variant, 10)).unwrap();
        let report = tr.train().unwrap();
        assert!(!report.diverged, "{variant} diverged");
        assert!(
            report.curve.iter().all(|(_, l)| l.is_finite()),
            "{variant} produced non-finite loss"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn data_parallel_quantized_allreduce_trains() {
    let (dir, reg, rt) = setup("dp");
    let cfg = base_cfg(&dir, "psq", 0);
    let meta = reg.meta("mlp", "psq", StepKind::Probe).unwrap();
    let probe = rt.executor(meta).unwrap();
    let dp = DataParallel {
        probe: &probe,
        workers: 4,
        allreduce_bits: 8.0,
        quantizer: GradQuantizer::Psq,
        momentum: 0.9,
    };
    let dataset = make_dataset(&cfg, &meta.input_shape, "synthimg");
    let init = reg.init_params("mlp").unwrap();
    let mut params = init.clone();
    let steps = dp
        .train(
            dataset.as_ref(),
            &mut params,
            30,
            0.05,
            Schedule::Constant,
            0,
            5.0,
            cfg.seed,
        )
        .unwrap();
    assert_eq!(steps.len(), 30);
    assert!(steps.iter().all(|s| s.loss.is_finite() && s.grad_norm_sq > 0.0));
    assert_ne!(params, init, "parameters never moved");
    let first = steps[0].loss;
    let last = steps.last().unwrap().loss;
    assert!(last < first, "dp loss did not decrease: {first} -> {last}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn checkpoint_roundtrip_preserves_evaluation() {
    let (dir, reg, rt) = setup("ckpt");
    let mut tr = Trainer::new(&rt, &reg, base_cfg(&dir, "qat", 15)).unwrap();
    tr.train().unwrap();
    let (loss0, acc0) = tr.evaluate(3).unwrap();

    let ck = Checkpoint {
        step: 15,
        params: tr.params.clone(),
        momentum: tr.momentum.clone(),
    };
    let meta_path = ck.save(&dir.join("ckpts")).unwrap();
    let back = Checkpoint::load(&meta_path).unwrap();

    let mut fresh = Trainer::new(&rt, &reg, base_cfg(&dir, "qat", 15)).unwrap();
    assert_ne!(fresh.params, back.params);
    fresh.params = back.params;
    let (loss1, acc1) = fresh.evaluate(3).unwrap();
    assert_eq!(loss0.to_bits(), loss1.to_bits());
    assert_eq!(acc0.to_bits(), acc1.to_bits());
    std::fs::remove_dir_all(&dir).ok();
}
