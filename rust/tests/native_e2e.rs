//! End-to-end tests for the native executor backend: generate artifacts,
//! run the full Trainer loop, the data-parallel coordinator, and a
//! checkpoint roundtrip — all without XLA/PJRT. These are the tier-1
//! guarantee that `cargo test` exercises the real training path on a
//! clean machine.

use std::path::{Path, PathBuf};

use statquant::config::TrainConfig;
use statquant::coordinator::{make_dataset, Checkpoint, DataParallel, ReduceMode, Schedule, Trainer};
use statquant::quant::GradQuantizer;
use statquant::runtime::{native, MlpSpec, Registry, Runtime, StepKind};

/// Fresh artifact dir + registry + native runtime for one test.
fn setup(tag: &str) -> (PathBuf, Registry, Runtime) {
    let dir = std::env::temp_dir().join(format!("sq_native_e2e_{}_{}", tag, std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    native::write_artifacts(&dir, &MlpSpec::default()).unwrap();
    let reg = Registry::open(&dir).unwrap();
    (dir, reg, Runtime::native())
}

fn base_cfg(artifacts: &Path, variant: &str, steps: u64) -> TrainConfig {
    TrainConfig {
        model: "mlp".into(),
        variant: variant.into(),
        steps,
        lr: 0.05,
        bits: 5.0,
        eval_every: steps.max(1),
        eval_batches: 4,
        seed: 7,
        artifacts_dir: artifacts.display().to_string(),
        out_dir: artifacts.join("runs").display().to_string(),
        ..TrainConfig::default()
    }
}

#[test]
fn trainer_converges_on_native_backend() {
    let (dir, reg, rt) = setup("train");
    let mut tr = Trainer::new(&rt, &reg, base_cfg(&dir, "qat", 60)).unwrap();
    let report = tr.train().unwrap();
    assert!(!report.diverged, "training diverged");
    assert_eq!(report.steps, 60);
    let first = report.curve[0].1;
    assert!(
        report.final_train_loss < 0.9 * first,
        "loss did not decrease: {first} -> {}",
        report.final_train_loss
    );
    assert!(report.final_eval_loss.is_finite());
    assert!(report.final_eval_acc > 0.2, "acc {}", report.final_eval_acc);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn training_is_deterministic_given_seed() {
    let (dir, reg, rt) = setup("det");
    let run = |seed: u64| {
        let mut cfg = base_cfg(&dir, "psq", 20);
        cfg.seed = seed;
        let mut tr = Trainer::new(&rt, &reg, cfg).unwrap();
        tr.train().unwrap().params
    };
    let a = run(7);
    let b = run(7);
    let c = run(8);
    assert_eq!(a, b, "same seed must replay bit-for-bit");
    assert_ne!(a, c, "different seed must draw different SR noise");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn quantized_variants_train_without_divergence() {
    let (dir, reg, rt) = setup("variants");
    for variant in ["ptq", "psq", "bhq"] {
        let mut tr = Trainer::new(&rt, &reg, base_cfg(&dir, variant, 10)).unwrap();
        let report = tr.train().unwrap();
        assert!(!report.diverged, "{variant} diverged");
        assert!(
            report.curve.iter().all(|(_, l)| l.is_finite()),
            "{variant} produced non-finite loss"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn data_parallel_quantized_allreduce_trains() {
    let (dir, reg, rt) = setup("dp");
    let cfg = base_cfg(&dir, "psq", 0);
    let meta = reg.meta("mlp", "psq", StepKind::Probe).unwrap();
    let probe = rt.executor(meta).unwrap();
    let dp = DataParallel {
        probe: &probe,
        workers: 4,
        allreduce_bits: 8.0,
        quantizer: GradQuantizer::Psq,
        momentum: 0.9,
        threads: 1,
        mode: ReduceMode::Dense,
    };
    let dataset = make_dataset(&cfg, &meta.input_shape, "synthimg");
    let init = reg.init_params("mlp").unwrap();
    let mut params = init.clone();
    let steps = dp
        .train(
            dataset.as_ref(),
            &mut params,
            30,
            0.05,
            Schedule::Constant,
            0,
            5.0,
            cfg.seed,
        )
        .unwrap();
    assert_eq!(steps.len(), 30);
    assert!(steps.iter().all(|s| s.loss.is_finite() && s.grad_norm_sq > 0.0));
    assert_ne!(params, init, "parameters never moved");
    let first = steps[0].loss;
    let last = steps.last().unwrap().loss;
    assert!(last < first, "dp loss did not decrease: {first} -> {last}");
    std::fs::remove_dir_all(&dir).ok();
}

/// The ring engine's determinism contract (ISSUE 8): for a fixed config
/// + seed, the final parameters are bitwise identical whether the ring
/// schedule runs serially or on a pool of `workers` threads — SR noise
/// is keyed per (step, worker, segment) and every reduction order is
/// fixed by index, never by scheduling.
#[test]
fn ring_allreduce_bitwise_deterministic_across_thread_counts() {
    let (dir, reg, rt) = setup("ringdet");
    let meta = reg.meta("mlp", "psq", StepKind::Probe).unwrap();
    let probe = rt.executor(meta).unwrap();
    let cfg = base_cfg(&dir, "psq", 0);
    let dataset = make_dataset(&cfg, &meta.input_shape, "synthimg");
    for quantizer in [GradQuantizer::Psq, GradQuantizer::Bhq] {
        for workers in [1usize, 2, 4] {
            let run = |threads: usize| {
                let dp = DataParallel {
                    probe: &probe,
                    workers,
                    allreduce_bits: 4.0,
                    quantizer,
                    momentum: 0.9,
                    threads,
                    mode: ReduceMode::Ring,
                };
                let mut params = reg.init_params("mlp").unwrap();
                let hist = dp
                    .train(
                        dataset.as_ref(),
                        &mut params,
                        8,
                        0.05,
                        Schedule::Cosine,
                        1,
                        5.0,
                        3,
                    )
                    .unwrap();
                let losses: Vec<u64> = hist.iter().map(|s| s.loss.to_bits()).collect();
                let bits: Vec<u32> = params.iter().map(|v| v.to_bits()).collect();
                (bits, losses)
            };
            let serial = run(1);
            let pooled = run(workers);
            assert_eq!(
                serial, pooled,
                "{quantizer:?} workers={workers}: thread count changed the bits"
            );
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// At `allreduce_bits = 0` the ring all-reduce reproduces the dense fp32
/// average *exactly* (the documented contract: canonical worker order
/// with the same fused 1/W multiply), so dense and ring runs — serial or
/// pooled — end in bitwise-identical parameters.
#[test]
fn ring_at_zero_bits_matches_dense_average_bitwise() {
    let (dir, reg, rt) = setup("ringzero");
    let meta = reg.meta("mlp", "qat", StepKind::Probe).unwrap();
    let probe = rt.executor(meta).unwrap();
    let cfg = base_cfg(&dir, "qat", 0);
    let dataset = make_dataset(&cfg, &meta.input_shape, "synthimg");
    for workers in [2usize, 4, 5] {
        let run = |mode: ReduceMode, threads: usize| {
            let dp = DataParallel {
                probe: &probe,
                workers,
                allreduce_bits: 0.0,
                quantizer: GradQuantizer::Psq,
                momentum: 0.9,
                threads,
                mode,
            };
            let mut params = reg.init_params("mlp").unwrap();
            dp.train(
                dataset.as_ref(),
                &mut params,
                6,
                0.05,
                Schedule::Constant,
                0,
                5.0,
                9,
            )
            .unwrap();
            params.iter().map(|v| v.to_bits()).collect::<Vec<u32>>()
        };
        let dense = run(ReduceMode::Dense, 1);
        let ring_serial = run(ReduceMode::Ring, 1);
        let ring_pooled = run(ReduceMode::Ring, workers);
        assert_eq!(dense, ring_serial, "workers={workers} serial ring != dense");
        assert_eq!(dense, ring_pooled, "workers={workers} pooled ring != dense");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Threaded ring training actually trains (loss decreases) and moves
/// parameters, with quantized payloads on.
#[test]
fn ring_allreduce_threaded_trains() {
    let (dir, reg, rt) = setup("ringtrain");
    let meta = reg.meta("mlp", "psq", StepKind::Probe).unwrap();
    let probe = rt.executor(meta).unwrap();
    let cfg = base_cfg(&dir, "psq", 0);
    let dataset = make_dataset(&cfg, &meta.input_shape, "synthimg");
    let dp = DataParallel {
        probe: &probe,
        workers: 4,
        allreduce_bits: 8.0,
        quantizer: GradQuantizer::Psq,
        momentum: 0.9,
        threads: 4,
        mode: ReduceMode::Ring,
    };
    let init = reg.init_params("mlp").unwrap();
    let mut params = init.clone();
    let steps = dp
        .train(
            dataset.as_ref(),
            &mut params,
            30,
            0.05,
            Schedule::Constant,
            0,
            5.0,
            cfg.seed,
        )
        .unwrap();
    assert_eq!(steps.len(), 30);
    assert!(steps
        .iter()
        .all(|s| s.loss.is_finite() && s.grad_norm_sq > 0.0));
    assert_ne!(params, init, "parameters never moved");
    let first = steps[0].loss;
    let last = steps.last().unwrap().loss;
    assert!(last < first, "ring dp loss did not decrease: {first} -> {last}");
    std::fs::remove_dir_all(&dir).ok();
}

/// `train_data_parallel` writes the full run-dir artifact set and its
/// report round-trips through the ring engine.
#[test]
fn train_data_parallel_writes_run_artifacts() {
    let (dir, reg, rt) = setup("dpdriver");
    let mut cfg = base_cfg(&dir, "psq", 20);
    cfg.workers = 4;
    cfg.dp_threads = 2;
    cfg.dp_mode = "ring".into();
    cfg.allreduce_bits = 4.0;
    let report = statquant::coordinator::train_data_parallel(&rt, &reg, cfg.clone()).unwrap();
    assert_eq!(report.steps, 20);
    assert!(!report.diverged);
    assert!(report.final_eval_loss.is_finite());
    let run_dir = Path::new(&cfg.out_dir).join(cfg.run_name());
    for f in ["log.jsonl", "curve.csv"] {
        assert!(run_dir.join(f).exists(), "missing {f}");
    }
    if statquant::obs::enabled() {
        let trace = std::fs::read_to_string(run_dir.join("trace.json")).unwrap();
        assert!(trace.contains("ring/"), "no ring/ spans in trace.json");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn checkpoint_roundtrip_preserves_evaluation() {
    let (dir, reg, rt) = setup("ckpt");
    let mut tr = Trainer::new(&rt, &reg, base_cfg(&dir, "qat", 15)).unwrap();
    tr.train().unwrap();
    let (loss0, acc0) = tr.evaluate(3).unwrap();

    let ck = Checkpoint {
        step: 15,
        params: tr.params.clone(),
        momentum: tr.momentum.clone(),
    };
    let meta_path = ck.save(&dir.join("ckpts")).unwrap();
    let back = Checkpoint::load(&meta_path).unwrap();

    let mut fresh = Trainer::new(&rt, &reg, base_cfg(&dir, "qat", 15)).unwrap();
    assert_ne!(fresh.params, back.params);
    fresh.params = back.params;
    let (loss1, acc1) = fresh.evaluate(3).unwrap();
    assert_eq!(loss0.to_bits(), loss1.to_bits());
    assert_eq!(acc0.to_bits(), acc1.to_bits());
    std::fs::remove_dir_all(&dir).ok();
}
