//! Statistical-conformance suite (ISSUE 8): the paper's two core
//! statistical claims as executable invariants.
//!
//! - **Thm 1 (unbiasedness):** every gradient quantizer is a
//!   deterministic affine transform composed with stochastic rounding,
//!   so `E[Q(x)] = x` elementwise. Checked by averaging K independent
//!   draws and requiring the deviation to sit inside a CLT band derived
//!   from the *empirical* per-element variance of those same draws.
//! - **Thm 2 (variance ordering):** `Var(BHQ) <= Var(PSQ) <= Var(PTQ)`
//!   on gradients with the paper's heavy-tailed row-outlier structure
//!   (§4.2: a few huge sample rows dominate the per-tensor range).
//!
//! These run on the pure quant stack — no artifacts needed — so the
//! suite is cheap enough for debug CI yet tight enough to catch a
//! mean-shifting regression in any quantizer.

use statquant::quant::{nbins, GradQuantizer, Mat};
use statquant::util::rng::Pcg32;

/// Undetectable-drift floor for a CLT band on SR draws: an element whose
/// bin-flip probability is O(1/K) may see *zero* flips in K draws, making
/// the empirical SE zero while the mean sits up to ~bin/K away from the
/// input. Bound the bin by the global range (doubled for BHQ, whose bins
/// live in Householder-transformed space where element magnitudes can
/// grow by the group mixing).
fn drift_floor(x: &Mat, bits: f32, k: usize) -> f64 {
    let (lo, hi) = x.minmax();
    let bin = 2.0 * f64::from(hi - lo) / f64::from(nbins(bits));
    12.0 * bin / k as f64 + 1e-7
}

/// Row-outlier matrix: `outliers` rows at scale 10, the rest at 0.01 —
/// the §4.2 gradient structure where per-tensor scaling collapses.
fn heavy_tailed(n: usize, d: usize, outliers: usize, seed: u64) -> Mat {
    let mut rng = Pcg32::new(seed, 0);
    let mut m = Mat::zeros(n, d);
    for i in 0..n {
        let s = if i < outliers { 10.0 } else { 0.01 };
        for v in m.row_mut(i) {
            *v = rng.normal() * s;
        }
    }
    m
}

/// Thm 1: the mean of K SR draws converges to the input elementwise,
/// within z = 6 empirical standard errors plus the rare-flip drift
/// floor. Seeds are fixed, so a pass is a stable pass; z = 6 over ~10^3
/// elements puts the false-alarm probability of an *unbiased* quantizer
/// near zero, while a systematic shift of half a bin (~50x the floor)
/// fails hard as K shrinks the band.
#[test]
fn unbiasedness_within_clt_tolerance() {
    let x = heavy_tailed(12, 24, 1, 11);
    let bits = 3.0;
    let k = 3000usize;
    for q in GradQuantizer::PAPER {
        let mut rng = Pcg32::new(99, 17);
        let mut sum = vec![0.0f64; x.len()];
        let mut sumsq = vec![0.0f64; x.len()];
        for _ in 0..k {
            let out = q.apply(&x, bits, &mut rng);
            for (j, &v) in out.data.iter().enumerate() {
                let v = f64::from(v);
                sum[j] += v;
                sumsq[j] += v * v;
            }
        }
        let kf = k as f64;
        let floor = drift_floor(&x, bits, k);
        let mut worst = 0.0f64;
        for (j, &v) in x.data.iter().enumerate() {
            let mean = sum[j] / kf;
            let var = (sumsq[j] / kf - mean * mean).max(0.0);
            let se = (var / kf).sqrt();
            let dev = (mean - f64::from(v)).abs();
            let tol = 6.0 * se + floor;
            assert!(
                dev <= tol,
                "{q:?} elem {j}: |E[Q(x)] - x| = {dev:.3e} > {tol:.3e} (se {se:.3e})"
            );
            worst = worst.max(if se > 0.0 { dev / se } else { 0.0 });
        }
        // sanity: the band is actually exercised, not vacuously wide
        assert!(worst > 0.0, "{q:?}: all draws identical — SR not engaged?");
    }
}

/// Thm 2 on heavy-tailed row-outlier matrices across several shapes and
/// outlier counts. Empirical MSE over many draws; the ordering must hold
/// with a 2% slack (on these inputs the true gaps are multiples, so the
/// slack only absorbs Monte-Carlo noise).
#[test]
fn thm2_variance_ordering_on_row_outlier_matrices() {
    let reps = 250;
    for (n, d, outliers, seed) in [
        (16usize, 32usize, 1usize, 7u64),
        (24, 16, 2, 13),
        (8, 64, 1, 29),
    ] {
        let x = heavy_tailed(n, d, outliers, seed);
        let var = |q: GradQuantizer| {
            let mut rng = Pcg32::new(seed ^ 0xABCD, 3);
            let mut acc = 0.0f64;
            for _ in 0..reps {
                acc += q.apply(&x, 4.0, &mut rng).sq_err(&x);
            }
            acc / f64::from(reps as u32)
        };
        let (vp, vs, vb) = (
            var(GradQuantizer::Ptq),
            var(GradQuantizer::Psq),
            var(GradQuantizer::Bhq),
        );
        assert!(
            vb <= vs * 1.05,
            "({n},{d},{outliers}): Var(BHQ) {vb:.4e} > Var(PSQ) {vs:.4e}"
        );
        assert!(
            vs <= vp * 1.05,
            "({n},{d},{outliers}): Var(PSQ) {vs:.4e} > Var(PTQ) {vp:.4e}"
        );
        // The PTQ/PSQ gap is *strict* on outlier inputs — per-tensor
        // scaling pays the full outlier range on every small row, a
        // 4-14x measured gap on these shapes (Thm 2's point). BHQ's
        // margin over PSQ is shape-dependent, so only the ordering is
        // asserted for it above.
        assert!(
            vp > vs * 1.5,
            "({n},{d},{outliers}): PTQ/PSQ gap collapsed: ptq {vp:.4e} psq {vs:.4e} bhq {vb:.4e}"
        );
    }
}

/// The same two invariants survive the ring-segment path: segment
/// quantization (reshaped chunks, triple-keyed seeds) is still unbiased,
/// and its variance keeps the Thm-2 ordering for PSQ vs PTQ.
#[test]
fn segment_path_stays_unbiased() {
    use statquant::quant::segment::quantize_slice;
    let x = heavy_tailed(1, 96, 1, 5);
    let k = 3000usize;
    for q in GradQuantizer::PAPER {
        let mut sum = vec![0.0f64; x.data.len()];
        let mut sumsq = vec![0.0f64; x.data.len()];
        for rep in 0..k {
            let mut rng = Pcg32::new(rep as u64, 21);
            let (out, _) = quantize_slice(q, &x.data, 3.0, 32, &mut rng);
            for (j, &v) in out.iter().enumerate() {
                let v = f64::from(v);
                sum[j] += v;
                sumsq[j] += v * v;
            }
        }
        let kf = k as f64;
        let floor = drift_floor(&x, 3.0, k);
        for (j, &v) in x.data.iter().enumerate() {
            let mean = sum[j] / kf;
            let var = (sumsq[j] / kf - mean * mean).max(0.0);
            let se = (var / kf).sqrt();
            let dev = (mean - f64::from(v)).abs();
            assert!(
                dev <= 6.0 * se + floor,
                "{q:?} segment elem {j}: dev {dev:.3e} > {:.3e}",
                6.0 * se + floor
            );
        }
    }
}
