//! Integration tests over the real AOT artifacts (require
//! `make artifacts`; each test skips gracefully when artifacts are
//! missing so `cargo test` stays green on a fresh checkout).
//!
//! These are the end-to-end guarantees: the Rust runtime loads the HLO
//! the Python side lowered, the ABI matches the metadata, training
//! reduces loss, probes are unbiased, and the Rust-native quantizers
//! agree statistically with the in-graph (Pallas) ones.

use statquant::config::TrainConfig;
use statquant::coordinator::{DataParallel, Schedule, Trainer};
use statquant::data::Dataset;
use statquant::experiments::common::warm_params;
use statquant::quant::GradQuantizer;
use statquant::runtime::{HostTensor, Registry, Runtime, StepKind};
use statquant::stats::GradVarianceProbe;

fn setup() -> Option<(Runtime, Registry)> {
    let reg = match Registry::open("artifacts") {
        Ok(r) => r,
        Err(_) => {
            eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
            return None;
        }
    };
    if reg.meta("mlp", "ptq", StepKind::Train).is_err() {
        eprintln!("SKIP: mlp artifacts missing");
        return None;
    }
    let rt = Runtime::cpu().expect("pjrt cpu");
    Some((rt, reg))
}

fn mlp_cfg(variant: &str) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.model = "mlp".into();
    cfg.variant = variant.into();
    cfg.steps = 60;
    cfg.lr = 0.05;
    cfg.bits = 5.0;
    cfg.eval_every = 30;
    cfg.out_dir = std::env::temp_dir()
        .join(format!("sq_it_{}", std::process::id()))
        .display()
        .to_string();
    cfg
}

#[test]
fn registry_discovers_all_mlp_artifacts() {
    let Some((_rt, reg)) = setup() else { return };
    for variant in ["exact", "qat", "ptq", "psq", "bhq"] {
        for step in [StepKind::Train, StepKind::Probe] {
            let meta = reg.meta("mlp", variant, step).expect("meta");
            assert!(meta.hlo_path.exists(), "{:?} missing", meta.hlo_path);
            assert_eq!(meta.n_params, reg.init_params("mlp").unwrap().len());
        }
    }
    assert!(reg.meta("mlp", "qat", StepKind::Eval).is_ok());
    assert!(reg.meta("mlp", "qat", StepKind::ActGrad).is_ok());
}

#[test]
fn abi_validation_rejects_bad_inputs() {
    let Some((rt, reg)) = setup() else { return };
    let exec = rt
        .executor(reg.meta("mlp", "qat", StepKind::Eval).unwrap())
        .unwrap();
    // wrong arity
    assert!(exec.run(&[HostTensor::F32(vec![0.0])]).is_err());
    // wrong element count
    let p = reg.init_params("mlp").unwrap();
    let bad = [
        HostTensor::F32(p.clone()),
        HostTensor::F32(vec![0.0; 3]), // x should be batch*in_dim
        HostTensor::I32(vec![0; 64]),
    ];
    assert!(exec.run(&bad).is_err());
    // wrong dtype for labels
    let meta = &exec.meta;
    let x_elems: usize = meta.inputs[1].numel();
    let bad_dtype = [
        HostTensor::F32(p),
        HostTensor::F32(vec![0.0; x_elems]),
        HostTensor::F32(vec![0.0; 64]),
    ];
    assert!(exec.run(&bad_dtype).is_err());
}

#[test]
fn training_reduces_loss_every_variant() {
    let Some((rt, reg)) = setup() else { return };
    for variant in ["exact", "qat", "ptq", "psq", "bhq"] {
        let mut tr = Trainer::new(&rt, &reg, mlp_cfg(variant)).unwrap();
        let report = tr.train().unwrap();
        assert!(!report.diverged, "{variant} diverged");
        let first = report.curve.first().unwrap().1;
        assert!(
            report.final_train_loss < first * 0.6,
            "{variant}: loss {first} -> {} (insufficient descent)",
            report.final_train_loss
        );
        assert!(
            report.final_eval_acc > 0.5,
            "{variant}: eval acc {}",
            report.final_eval_acc
        );
    }
}

#[test]
fn training_is_deterministic_given_seed() {
    let Some((rt, reg)) = setup() else { return };
    let run = |seed: u64| {
        let mut cfg = mlp_cfg("ptq");
        cfg.steps = 20;
        cfg.seed = seed;
        let mut tr = Trainer::new(&rt, &reg, cfg).unwrap();
        tr.train().unwrap().final_train_loss
    };
    assert_eq!(run(7), run(7));
    assert_ne!(run(7), run(8));
}

#[test]
fn probe_gradients_unbiased_vs_qat() {
    let Some((rt, reg)) = setup() else { return };
    let mut cfg = mlp_cfg("qat");
    cfg.out_dir = std::env::temp_dir()
        .join(format!("sq_it_warm_{}", std::process::id()))
        .display()
        .to_string();
    let params = warm_params(&rt, &reg, &cfg, 30).unwrap();

    let qat_exec = rt
        .executor(reg.meta("mlp", "qat", StepKind::Probe).unwrap())
        .unwrap();
    let qat = GradVarianceProbe::new(&qat_exec);
    let ds = statquant::coordinator::make_dataset(&cfg, &[64, 64], "synthimg");
    let b = ds.batch(5);
    let (g_ref, _) = qat.mean_gradient(&params, &b.x, &b.y, 8.0, 1, 0).unwrap();

    let exec = rt
        .executor(reg.meta("mlp", "ptq", StepKind::Probe).unwrap())
        .unwrap();
    let probe = GradVarianceProbe::new(&exec);
    let seeds = 48;
    let (mean, _) = probe.mean_gradient(&params, &b.x, &b.y, 5.0, seeds, 3).unwrap();
    let dot: f64 = mean.iter().zip(&g_ref).map(|(&a, &b)| a * b).sum();
    let na = mean.iter().map(|&a| a * a).sum::<f64>().sqrt();
    let nb = g_ref.iter().map(|&a| a * a).sum::<f64>().sqrt();
    let cos = dot / (na * nb).max(1e-30);
    assert!(cos > 0.97, "cos(E[fqt], qat) = {cos}");
}

#[test]
fn variance_ordering_through_real_model() {
    let Some((rt, reg)) = setup() else { return };
    let mut cfg = mlp_cfg("qat");
    cfg.out_dir = std::env::temp_dir()
        .join(format!("sq_it_vo_{}", std::process::id()))
        .display()
        .to_string();
    let params = warm_params(&rt, &reg, &cfg, 40).unwrap();
    let ds = statquant::coordinator::make_dataset(&cfg, &[64, 64], "synthimg");
    let b = ds.batch(77);
    let mut var = std::collections::HashMap::new();
    for q in ["ptq", "psq", "bhq"] {
        let exec = rt
            .executor(reg.meta("mlp", q, StepKind::Probe).unwrap())
            .unwrap();
        let probe = GradVarianceProbe::new(&exec);
        let rep = probe
            .quantization_variance(&params, &b.x, &b.y, 4.0, 10, 5)
            .unwrap();
        var.insert(q, rep.quant_variance);
    }
    // the paper's headline ordering through the full model graph
    assert!(var["ptq"] > var["psq"], "{var:?}");
    assert!(var["psq"] > var["bhq"], "{var:?}");
}

#[test]
fn bits_input_scales_variance_4x() {
    let Some((rt, reg)) = setup() else { return };
    let mut cfg = mlp_cfg("qat");
    cfg.out_dir = std::env::temp_dir()
        .join(format!("sq_it_4x_{}", std::process::id()))
        .display()
        .to_string();
    let params = warm_params(&rt, &reg, &cfg, 30).unwrap();
    let ds = statquant::coordinator::make_dataset(&cfg, &[64, 64], "synthimg");
    let b = ds.batch(88);
    let exec = rt
        .executor(reg.meta("mlp", "ptq", StepKind::Probe).unwrap())
        .unwrap();
    let probe = GradVarianceProbe::new(&exec);
    let v4 = probe
        .quantization_variance(&params, &b.x, &b.y, 4.0, 16, 9)
        .unwrap()
        .quant_variance;
    let v6 = probe
        .quantization_variance(&params, &b.x, &b.y, 6.0, 16, 9)
        .unwrap()
        .quant_variance;
    let ratio = v4 / v6.max(1e-30);
    // two bits => ~16x; allow generous MC slack
    assert!((6.0..50.0).contains(&ratio), "4->6 bit ratio {ratio}");
}

#[test]
fn eval_artifact_consistent_with_train_aux() {
    let Some((rt, reg)) = setup() else { return };
    let mut tr = Trainer::new(&rt, &reg, mlp_cfg("qat")).unwrap();
    let report = tr.train().unwrap();
    let (loss, acc) = tr.evaluate(8).unwrap();
    assert!(loss.is_finite() && (0.0..=1.0).contains(&acc));
    assert!((loss - report.final_eval_loss).abs() < 1e-6); // same eval path
}

#[test]
fn data_parallel_quantized_allreduce_trains() {
    let Some((rt, reg)) = setup() else { return };
    let exec = rt
        .executor(reg.meta("mlp", "qat", StepKind::Probe).unwrap())
        .unwrap();
    let cfg = mlp_cfg("qat");
    let ds = statquant::coordinator::make_dataset(&cfg, &[64, 64], "synthimg");
    let dp = DataParallel {
        probe: &exec,
        workers: 4,
        allreduce_bits: 6.0,
        quantizer: GradQuantizer::Psq,
        momentum: 0.9,
        threads: 1,
        mode: statquant::coordinator::ReduceMode::Dense,
    };
    let mut params = reg.init_params("mlp").unwrap();
    let hist = dp
        .train(ds.as_ref(), &mut params, 60, 0.05, Schedule::Cosine, 3, 8.0, 1)
        .unwrap();
    let first = hist.first().unwrap().loss;
    let last = hist.last().unwrap().loss;
    assert!(
        last < first * 0.6,
        "quantized all-reduce failed to train: {first} -> {last}"
    );
}

#[test]
fn actgrad_probe_shape_matches_meta() {
    let Some((rt, reg)) = setup() else { return };
    let meta = reg.meta("mlp", "qat", StepKind::ActGrad).unwrap();
    let exec = rt.executor(meta).unwrap();
    let params = reg.init_params("mlp").unwrap();
    let cfg = mlp_cfg("qat");
    let ds = statquant::coordinator::make_dataset(&cfg, &meta.input_shape, "synthimg");
    let b = ds.batch(0);
    let out = exec
        .run(&[
            HostTensor::F32(params),
            b.x,
            b.y,
            HostTensor::F32(vec![0.0]),
        ])
        .unwrap();
    let expect: usize = meta.probe_shape.iter().product();
    assert_eq!(out[0].len(), expect);
    // gradient of a mean cross-entropy at the tap must be non-trivial
    let g = out[0].as_f32().unwrap();
    assert!(g.iter().any(|&v| v != 0.0));
}

#[test]
fn checkpoint_resume_matches_continuous_eval() {
    let Some((rt, reg)) = setup() else { return };
    // train 30 steps, checkpoint, reload into a fresh trainer, eval must match
    let mut cfg = mlp_cfg("bhq");
    cfg.steps = 30;
    let mut tr = Trainer::new(&rt, &reg, cfg.clone()).unwrap();
    tr.train().unwrap();
    let (l1, a1) = tr.evaluate(4).unwrap();

    let ck = statquant::coordinator::Checkpoint {
        step: 30,
        params: tr.params.clone(),
        momentum: tr.momentum.clone(),
    };
    let dir = std::env::temp_dir().join(format!("sq_resume_{}", std::process::id()));
    let meta = ck.save(&dir).unwrap();

    let mut tr2 = Trainer::new(&rt, &reg, cfg).unwrap();
    let ck2 = statquant::coordinator::Checkpoint::load(&meta).unwrap();
    tr2.params = ck2.params;
    let (l2, a2) = tr2.evaluate(4).unwrap();
    assert_eq!(l1, l2);
    assert_eq!(a1, a2);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cnn_artifacts_load_and_step_if_present() {
    let Some((rt, reg)) = setup() else { return };
    if reg.meta("cnn", "bhq", StepKind::Train).is_err() {
        eprintln!("SKIP: cnn artifacts missing");
        return;
    }
    let mut cfg = mlp_cfg("bhq");
    cfg.model = "cnn".into();
    cfg.steps = 3;
    cfg.eval_every = 3;
    let mut tr = Trainer::new(&rt, &reg, cfg).unwrap();
    let report = tr.train().unwrap();
    assert_eq!(report.steps, 3);
    assert!(report.final_train_loss.is_finite());
}
