//! Bench: quantizer overhead — reproduces the shape of the paper's §4.3
//! overhead study ("computing the range takes 11ms for PTQ and 24ms for
//! PSQ/BHQ; the Householder transform ... 21ms; vs 480ms convolution").
//!
//! We measure, on a conv-layer-sized gradient (the paper's N=128, C=64,
//! H=W=56 flattened to the (N, D) sample view):
//!   * range reduction per-tensor (PTQ) and per-row (PSQ/BHQ),
//!   * the BHQ plan construction (App. D.5 heuristic — the "3us C++
//!     routine" of the paper),
//!   * the blockwise Householder transform (the 2ND-FLOPs transform),
//!   * full quantize-dequantize for each quantizer,
//!   * a same-shape f32 GEMM stand-in for the convolution it shadows.
//!
//! Claim to reproduce: total quantizer overhead is small relative to the
//! GEMM, and BHQ's extra cost over PSQ is the transform only.
//!
//! Run: `cargo bench --bench quantizers` (BENCH_BUDGET_MS to tune).

use statquant::quant::{bfp, bhq, fp8, nbins, psq, ptq, Mat};
use statquant::util::bench::Bench;
use statquant::util::rng::Pcg32;

fn gradient(n: usize, d: usize) -> Mat {
    // outlier-structured like a real late-training gradient
    let mut rng = Pcg32::new(7, 1);
    let mut m = Mat::zeros(n, d);
    for i in 0..n {
        let s = if i % 16 == 0 { 1.0 } else { 0.005 };
        for v in m.row_mut(i) {
            *v = rng.normal() * s;
        }
    }
    m
}

fn main() {
    // paper §4.3 layer: N=128 samples, D = C*H*W = 64*56*56 is 200k cols —
    // too large for a tight bench loop; use D=16384 and also a small case.
    let cases = [(128usize, 16_384usize), (32, 2_048)];
    let mut b = Bench::new();
    for (n, d) in cases {
        let g = gradient(n, d);
        let elems = (n * d) as f64;
        let nb = nbins(8.0);

        b.run(&format!("range/per-tensor {n}x{d}"), elems, || {
            std::hint::black_box(g.minmax());
        });
        b.run(&format!("range/per-row {n}x{d}"), elems, || {
            std::hint::black_box(g.row_minmax());
        });
        b.run(&format!("bhq/plan (D.5 heuristic) {n}x{d}"), n as f64, || {
            std::hint::black_box(bhq::build_plan(&g));
        });

        let mut rng = Pcg32::new(3, 3);
        let ptq_ns = b
            .run(&format!("quantize/ptq {n}x{d}"), elems, || {
                std::hint::black_box(ptq::quantize(&g, nb, &mut rng));
            })
            .median_ns;
        let mut rng = Pcg32::new(3, 4);
        let psq_ns = b
            .run(&format!("quantize/psq {n}x{d}"), elems, || {
                std::hint::black_box(psq::quantize(&g, nb, &mut rng));
            })
            .median_ns;
        let mut rng = Pcg32::new(3, 5);
        let bhq_ns = b
            .run(&format!("quantize/bhq {n}x{d}"), elems, || {
                std::hint::black_box(bhq::quantize(&g, nb, &mut rng));
            })
            .median_ns;

        // fused zero-allocation paths (same math + RNG stream as above;
        // output buffer and BHQ plan scratch are reused across iterations)
        let mut out = Mat::zeros(n, d);
        let mut rng = Pcg32::new(3, 3);
        let fused_ptq_ns = b
            .run(&format!("fused/ptq {n}x{d}"), elems, || {
                ptq::apply_into(&g, nb, &mut rng, &mut out);
                std::hint::black_box(&out);
            })
            .median_ns;
        let mut rng = Pcg32::new(3, 4);
        let fused_psq_ns = b
            .run(&format!("fused/psq {n}x{d}"), elems, || {
                psq::apply_into(&g, nb, &mut rng, &mut out);
                std::hint::black_box(&out);
            })
            .median_ns;
        let mut scratch = bhq::Scratch::default();
        let mut rng = Pcg32::new(3, 5);
        let fused_bhq_ns = b
            .run(&format!("fused/bhq {n}x{d}"), elems, || {
                bhq::apply_into(&g, nb, &mut rng, &mut scratch, &mut out);
                std::hint::black_box(&out);
            })
            .median_ns;

        // Derived per-quantizer gauges for the BENCH_quantizers.json
        // trajectory: elems/s of the fused path + fused-over-allocating
        // speedup, labeled by quantizer and shape.
        let m = statquant::obs::metrics();
        let shape = format!("{n}x{d}");
        for (q, alloc_ns, fused_ns) in [
            ("ptq", ptq_ns, fused_ptq_ns),
            ("psq", psq_ns, fused_psq_ns),
            ("bhq", bhq_ns, fused_bhq_ns),
        ] {
            let labels = [("quantizer", q), ("shape", shape.as_str())];
            m.gauge(
                &statquant::obs::registry::labeled("quant_fused_elems_per_sec", &labels),
                "fused quantize-dequantize throughput (median)",
            )
            .set(elems / (fused_ns.max(1.0) * 1e-9));
            m.gauge(
                &statquant::obs::registry::labeled("quant_fused_speedup", &labels),
                "fused apply_into speedup over the allocating quantize path (median)",
            )
            .set(alloc_ns / fused_ns.max(1.0));
        }
        let mut rng = Pcg32::new(3, 6);
        b.run(&format!("quantize/fp8 {n}x{d}"), elems, || {
            std::hint::black_box(fp8::quantize(&g, &mut rng));
        });
        let mut rng = Pcg32::new(3, 7);
        b.run(&format!("quantize/bfp {n}x{d}"), elems, || {
            std::hint::black_box(bfp::quantize(&g, nb, 64, &mut rng));
        });

        // the GEMM this quantization shadows: (n x d) @ (d x 64)
        let k = 64usize;
        let w: Vec<f32> = {
            let mut rng = Pcg32::new(9, 9);
            (0..d * k).map(|_| rng.normal() * 0.05).collect()
        };
        let flops = 2.0 * (n * d * k) as f64;
        b.run(&format!("gemm/f32 {n}x{d}x{k} (shadowed conv)"), flops, || {
            let mut out = vec![0.0f32; n * k];
            for i in 0..n {
                let row = g.row(i);
                for (kk, &x) in row.iter().enumerate() {
                    let wrow = &w[kk * k..(kk + 1) * k];
                    let orow = &mut out[i * k..(i + 1) * k];
                    for (o, &ww) in orow.iter_mut().zip(wrow) {
                        *o += x * ww;
                    }
                }
            }
            std::hint::black_box(out);
        });
    }
    b.finish("quantizers").expect("bench artifacts");
    println!("\nwrote results/bench/quantizers.csv + BENCH_quantizers.json");
}
