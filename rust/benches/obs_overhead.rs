//! Bench: observability overhead — the ISSUE-7 acceptance gate that the
//! instrumented hot loop stays within 5% of the uninstrumented baseline.
//!
//! Two layers:
//!   * micro: one counter inc / gauge set / histogram observe / span
//!     open+drop, obs enabled vs disabled (disabled must reduce to a
//!     relaxed atomic load);
//!   * end-to-end: the native mlp/ptq fused train step with obs on vs
//!     off, reported as a relative overhead percentage.
//!
//! Self-contained: writes its own native artifacts into a temp dir.
//!
//! Run: `cargo bench --bench obs_overhead` (BENCH_BUDGET_MS to tune).

use statquant::config::TrainConfig;
use statquant::coordinator::Trainer;
use statquant::obs;
use statquant::runtime::{native, MlpSpec, Registry, Runtime};
use statquant::util::bench::Bench;

const BUDGET_PCT: f64 = 5.0;

fn main() {
    let mut b = Bench::new();

    // --- micro primitives, obs on vs off -------------------------------
    let m = obs::metrics();
    let c = m.counter("bench_obs_counter_total", "overhead bench counter");
    let g = m.gauge("bench_obs_gauge", "overhead bench gauge");
    let h = m.histogram(
        "bench_obs_hist_seconds",
        "overhead bench histogram",
        &obs::registry::TIME_BUCKETS,
    );
    for on in [true, false] {
        obs::set_enabled(on);
        let tag = if on { "on" } else { "off" };
        b.run(&format!("micro/counter_inc obs_{tag}"), 1000.0, || {
            for _ in 0..1000 {
                c.inc();
            }
        });
        b.run(&format!("micro/gauge_set obs_{tag}"), 1000.0, || {
            for i in 0..1000 {
                g.set(i as f64);
            }
        });
        b.run(&format!("micro/hist_observe obs_{tag}"), 1000.0, || {
            for i in 0..1000 {
                h.observe(i as f64 * 1e-6);
            }
        });
        b.run(&format!("micro/span obs_{tag}"), 1000.0, || {
            for _ in 0..1000 {
                let _sp = obs::span("bench/span");
            }
        });
        obs::span::clear();
    }

    // --- end-to-end train step, obs on vs off ---------------------------
    let dir = std::env::temp_dir().join(format!("sq_obs_bench_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    native::write_artifacts(&dir, &MlpSpec::default()).expect("artifacts");
    let reg = Registry::open(&dir).expect("registry");
    let rt = Runtime::native();
    let cfg = TrainConfig {
        model: "mlp".into(),
        variant: "ptq".into(),
        bits: 5.0,
        steps: 1,
        artifacts_dir: dir.display().to_string(),
        out_dir: dir.join("runs").display().to_string(),
        ..TrainConfig::default()
    };

    let mut per_mode = [0.0f64; 2];
    for (idx, on) in [true, false].into_iter().enumerate() {
        obs::set_enabled(on);
        let tag = if on { "on" } else { "off" };
        let mut tr = Trainer::new(&rt, &reg, cfg.clone()).expect("trainer");
        let elems = tr.train_exec.meta.input_shape.iter().product::<usize>() as f64;
        let mut step = 0u64;
        let r = b.run(&format!("train_step/mlp/ptq obs_{tag}"), elems, || {
            tr.train_step_bench(step).expect("step");
            step += 1;
        });
        per_mode[idx] = r.median_ns;
        obs::span::clear();
    }
    let (on_ns, off_ns) = (per_mode[0], per_mode[1]);
    let overhead_pct = 100.0 * (on_ns - off_ns) / off_ns.max(1.0);
    println!(
        "\nobs overhead on train step: {overhead_pct:+.2}% \
         (on {on_ns:.0} ns, off {off_ns:.0} ns, budget {BUDGET_PCT}%)"
    );
    if overhead_pct > BUDGET_PCT {
        println!("WARNING: overhead exceeds the {BUDGET_PCT}% budget");
    }

    // gauges are enable-gated: re-enable before exporting the results
    obs::set_enabled(true);
    b.finish("obs_overhead").expect("bench artifacts");
    println!("wrote results/bench/obs_overhead.csv + BENCH_obs_overhead.json");
    std::fs::remove_dir_all(&dir).ok();
}
