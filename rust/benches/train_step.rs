//! Bench: end-to-end train-step latency per (model, variant) — the L3
//! hot path (Table-1's cost axis, and the §Perf baseline for the
//! optimization log in EXPERIMENTS.md).
//!
//! Measures a full coordinator step: batch synthesis + PJRT execute of
//! the fused fwd+bwd+update artifact + state swap, and separately the
//! eval step and data generation, to localize where time goes.
//!
//! Requires `make artifacts`. Models/variants chosen to finish quickly;
//! override with BENCH_MODELS="mlp,cnn" BENCH_VARIANTS="qat,bhq".
//!
//! Run: `cargo bench --bench train_step`

use statquant::config::TrainConfig;
use statquant::coordinator::Trainer;
use statquant::data::Dataset;
use statquant::runtime::{Registry, Runtime};
use statquant::util::bench::Bench;

fn main() {
    let rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping train_step bench: {e}");
            return;
        }
    };
    let reg = match Registry::open("artifacts") {
        Ok(r) => r,
        Err(e) => {
            eprintln!("skipping train_step bench (run `make artifacts`): {e}");
            return;
        }
    };
    let models = std::env::var("BENCH_MODELS").unwrap_or_else(|_| "mlp,cnn,transformer".into());
    let variants =
        std::env::var("BENCH_VARIANTS").unwrap_or_else(|_| "exact,qat,ptq,psq,bhq".into());

    let mut b = Bench::new();
    for model in models.split(',') {
        // data generation cost (off the executor path)
        {
            let mut cfg = TrainConfig::default();
            cfg.model = model.into();
            cfg.variant = "qat".into();
            if let Ok(tr) = Trainer::new(&rt, &reg, cfg) {
                let ds: &dyn Dataset = tr.dataset.as_ref();
                let mut step = 0u64;
                b.run(&format!("data/batch {model}"), 1.0, || {
                    std::hint::black_box(ds.batch(step));
                    step += 1;
                });
            }
        }
        for variant in variants.split(',') {
            let mut cfg = TrainConfig::default();
            cfg.model = model.into();
            cfg.variant = variant.into();
            cfg.bits = 5.0;
            cfg.steps = 1;
            cfg.out_dir = "results/bench_runs".into();
            let mut tr = match Trainer::new(&rt, &reg, cfg) {
                Ok(t) => t,
                Err(e) => {
                    eprintln!("skip {model}/{variant}: {e}");
                    continue;
                }
            };
            let batch_elems = tr.train_exec.meta.input_shape.iter().product::<usize>() as f64;
            let mut step = 0u64;
            b.run(&format!("train_step/{model}/{variant}"), batch_elems, || {
                tr.train_step_bench(step).expect("step");
                step += 1;
            });
        }
        // eval step
        let mut cfg = TrainConfig::default();
        cfg.model = model.into();
        cfg.variant = "qat".into();
        cfg.out_dir = "results/bench_runs".into();
        if let Ok(tr) = Trainer::new(&rt, &reg, cfg) {
            b.run(&format!("eval_step/{model}"), 1.0, || {
                std::hint::black_box(tr.evaluate(1).expect("eval"));
            });
        }
    }
    b.finish("train_step").expect("bench artifacts");
    println!("\nwrote results/bench/train_step.csv + BENCH_train_step.json");
}
