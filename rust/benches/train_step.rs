//! Bench: end-to-end train-step latency per (model, variant) — the L3
//! hot path (Table-1's cost axis, and the §Perf baseline for the
//! optimization log in EXPERIMENTS.md).
//!
//! Measures a full coordinator step: batch synthesis + PJRT execute of
//! the fused fwd+bwd+update artifact + state swap, and separately the
//! eval step and data generation, to localize where time goes.
//!
//! Requires `make artifacts`. Models/variants chosen to finish quickly;
//! override with BENCH_MODELS="mlp,cnn" BENCH_VARIANTS="qat,bhq".
//!
//! Run: `cargo bench --bench train_step`

use statquant::config::TrainConfig;
use statquant::coordinator::{make_dataset, DataParallel, ReduceMode, Schedule, Trainer};
use statquant::data::Dataset;
use statquant::quant::GradQuantizer;
use statquant::runtime::{
    native, ComputeMode, ExecutorBackend, HostTensor, MlpSpec, NativeExecutor, Registry, Runtime,
    StepKind,
};
use statquant::util::bench::Bench;
use statquant::util::rng::Pcg32;

fn main() {
    let mut b = Bench::new();
    // The kernel-layer bench needs no artifacts on disk — it drives the
    // native backend directly — so it runs (and BENCH_train_step.json is
    // written) even where `make artifacts` hasn't.
    bench_native_kernels(&mut b);
    bench_int8(&mut b);
    match (Runtime::cpu(), Registry::open("artifacts")) {
        (Ok(rt), Ok(reg)) => {
            bench_trainer(&mut b, &rt, &reg);
            bench_data_parallel(&mut b, &rt, &reg);
        }
        (Err(e), _) => eprintln!("skipping trainer/dp benches: {e}"),
        (_, Err(e)) => eprintln!("skipping trainer/dp benches (run `make artifacts`): {e}"),
    }
    b.finish("train_step").expect("bench artifacts");
    println!("\nwrote results/bench/train_step.csv + BENCH_train_step.json");
}

/// Blocked-kernel vs per-sample-reference train step on the default
/// `MlpSpec` geometry (ISSUE 9 acceptance): the `native_step_speedup`
/// gauge is the exact-variant median ratio, with per-variant ratios as
/// labeled gauges (FQT variants include the fused quantizer path).
fn bench_native_kernels(b: &mut Bench) {
    let spec = MlpSpec::default();
    let params = native::init_params(&spec);
    let mut rng = Pcg32::new(0xBE7C, 5);
    let x: Vec<f32> = (0..spec.batch * spec.in_dim).map(|_| rng.normal()).collect();
    let y: Vec<i32> = (0..spec.batch)
        .map(|_| rng.below(spec.classes as u32) as i32)
        .collect();
    let blocked = NativeExecutor::default();
    let reference = NativeExecutor::reference();
    let m = statquant::obs::metrics();
    let mut headline = 1.0f64;
    for variant in ["exact", "psq", "bhq"] {
        let meta = native::meta_for(&spec, variant, StepKind::Train);
        let inputs = [
            HostTensor::F32(params.clone()),
            HostTensor::F32(vec![0.0; params.len()]),
            HostTensor::F32(x.clone()),
            HostTensor::I32(y.clone()),
            HostTensor::F32(vec![1.0]),
            HostTensor::F32(vec![0.05]),
            HostTensor::F32(vec![4.0]),
        ];
        let reference_ns = b
            .run(&format!("native/reference/{variant}"), 1.0, || {
                std::hint::black_box(reference.execute(&meta, &inputs).expect("reference step"));
            })
            .median_ns;
        let blocked_ns = b
            .run(&format!("native/blocked/{variant}"), 1.0, || {
                std::hint::black_box(blocked.execute(&meta, &inputs).expect("blocked step"));
            })
            .median_ns;
        let speedup = reference_ns / blocked_ns.max(1.0);
        println!("native step speedup ({variant}): {speedup:.2}x");
        m.gauge(
            &statquant::obs::registry::labeled(
                "native_step_speedup_variant",
                &[("variant", variant)],
            ),
            "blocked-kernel native train-step speedup over the per-sample reference (median)",
        )
        .set(speedup);
        if variant == "exact" {
            headline = speedup;
        }
    }
    m.gauge(
        "native_step_speedup",
        "blocked-kernel native train-step speedup over the per-sample reference \
         (exact variant, default MlpSpec, median ratio)",
    )
    .set(headline);
}

/// Integer-code vs simulate train step on the default `MlpSpec`
/// geometry (ISSUE 10 acceptance): `int8_step_speedup` is the PTQ
/// bits=4 median ratio of the simulate-mode blocked step over the
/// int8-mode blocked step; bits=8 and PSQ land as labeled gauges. Like
/// `bench_native_kernels`, this needs no artifacts on disk, so the CI
/// gate (`bench-check --min int8_step_speedup=1.2`) always has data.
fn bench_int8(b: &mut Bench) {
    let spec = MlpSpec::default();
    let params = native::init_params(&spec);
    let mut rng = Pcg32::new(0x1E8, 5);
    let x: Vec<f32> = (0..spec.batch * spec.in_dim).map(|_| rng.normal()).collect();
    let y: Vec<i32> = (0..spec.batch)
        .map(|_| rng.below(spec.classes as u32) as i32)
        .collect();
    let simulate = NativeExecutor::default();
    let int8 = NativeExecutor::default().with_compute(ComputeMode::Int8);
    let m = statquant::obs::metrics();
    let mut headline = 1.0f64;
    for (variant, bits) in [("ptq", 4.0f32), ("ptq", 8.0), ("psq", 4.0)] {
        let meta = native::meta_for(&spec, variant, StepKind::Train);
        let inputs = [
            HostTensor::F32(params.clone()),
            HostTensor::F32(vec![0.0; params.len()]),
            HostTensor::F32(x.clone()),
            HostTensor::I32(y.clone()),
            HostTensor::F32(vec![1.0]),
            HostTensor::F32(vec![0.05]),
            HostTensor::F32(vec![bits]),
        ];
        let simulate_ns = b
            .run(&format!("native/simulate/{variant}_b{bits}"), 1.0, || {
                std::hint::black_box(simulate.execute(&meta, &inputs).expect("simulate step"));
            })
            .median_ns;
        let int8_ns = b
            .run(&format!("native/int8/{variant}_b{bits}"), 1.0, || {
                std::hint::black_box(int8.execute(&meta, &inputs).expect("int8 step"));
            })
            .median_ns;
        let speedup = simulate_ns / int8_ns.max(1.0);
        println!("int8 step speedup ({variant} @ {bits} bits): {speedup:.2}x");
        m.gauge(
            &statquant::obs::registry::labeled(
                "int8_step_speedup_variant",
                &[("variant", variant), ("bits", &format!("{bits}"))],
            ),
            "integer-code train-step speedup over the simulate-mode blocked step (median)",
        )
        .set(speedup);
        if variant == "ptq" && bits == 4.0 {
            headline = speedup;
        }
    }
    m.gauge(
        "int8_step_speedup",
        "integer-code (--compute int8) native train-step speedup over the \
         simulate-mode blocked step (PTQ, 4 bits, default MlpSpec, median ratio)",
    )
    .set(headline);
}

fn bench_trainer(b: &mut Bench, rt: &Runtime, reg: &Registry) {
    let models = std::env::var("BENCH_MODELS").unwrap_or_else(|_| "mlp,cnn,transformer".into());
    let variants =
        std::env::var("BENCH_VARIANTS").unwrap_or_else(|_| "exact,qat,ptq,psq,bhq".into());

    for model in models.split(',') {
        // data generation cost (off the executor path)
        {
            let mut cfg = TrainConfig::default();
            cfg.model = model.into();
            cfg.variant = "qat".into();
            if let Ok(tr) = Trainer::new(rt, reg, cfg) {
                let ds: &dyn Dataset = tr.dataset.as_ref();
                let mut step = 0u64;
                b.run(&format!("data/batch {model}"), 1.0, || {
                    std::hint::black_box(ds.batch(step));
                    step += 1;
                });
            }
        }
        for variant in variants.split(',') {
            let mut cfg = TrainConfig::default();
            cfg.model = model.into();
            cfg.variant = variant.into();
            cfg.bits = 5.0;
            cfg.steps = 1;
            cfg.out_dir = "results/bench_runs".into();
            let mut tr = match Trainer::new(rt, reg, cfg) {
                Ok(t) => t,
                Err(e) => {
                    eprintln!("skip {model}/{variant}: {e}");
                    continue;
                }
            };
            let batch_elems = tr.train_exec.meta.input_shape.iter().product::<usize>() as f64;
            let mut step = 0u64;
            b.run(&format!("train_step/{model}/{variant}"), batch_elems, || {
                tr.train_step_bench(step).expect("step");
                step += 1;
            });
        }
        // eval step
        let mut cfg = TrainConfig::default();
        cfg.model = model.into();
        cfg.variant = "qat".into();
        cfg.out_dir = "results/bench_runs".into();
        if let Ok(tr) = Trainer::new(rt, reg, cfg) {
            b.run(&format!("eval_step/{model}"), 1.0, || {
                std::hint::black_box(tr.evaluate(1).expect("eval"));
            });
        }
    }
}

/// Serial vs threaded data-parallel engine (ISSUE 8 acceptance): 4-worker
/// PSQ training, dense serial vs ring serial vs ring on a pool sized to
/// the machine. Each iteration runs a fixed number of full dp steps, so
/// units/s is directly steps/s. The derived `dp_ring_speedup` gauge
/// (threaded-ring vs serial-dense median) lands in BENCH_train_step.json;
/// the >= 1.8x criterion is meaningful only on a >= 4-core runner — on
/// fewer cores the pool degrades to roughly serial throughput.
fn bench_data_parallel(b: &mut Bench, rt: &Runtime, reg: &Registry) {
    const WORKERS: usize = 4;
    const STEPS_PER_ITER: u64 = 4;
    let meta = match reg.meta("mlp", "psq", StepKind::Probe) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skip dp bench: {e}");
            return;
        }
    };
    let probe = match rt.executor(meta) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("skip dp bench: {e}");
            return;
        }
    };
    let cfg = TrainConfig {
        model: "mlp".into(),
        variant: "psq".into(),
        ..TrainConfig::default()
    };
    let dataset = make_dataset(&cfg, &meta.input_shape, "synthimg");
    let init = reg.init_params("mlp").expect("init params");
    let cores = std::thread::available_parallelism().map_or(1, |n| n.get());
    let pool = cores.min(WORKERS);

    let mut run_dp = |name: &str, mode: ReduceMode, threads: usize| {
        let dp = DataParallel {
            probe: &probe,
            workers: WORKERS,
            allreduce_bits: 4.0,
            quantizer: GradQuantizer::Psq,
            momentum: 0.9,
            threads,
            mode,
        };
        let mut step_base = 0u64;
        b.run(name, STEPS_PER_ITER as f64, || {
            let mut params = init.clone();
            dp.train(
                dataset.as_ref(),
                &mut params,
                STEPS_PER_ITER,
                0.05,
                Schedule::Constant,
                0,
                5.0,
                step_base, // vary the seed so iterations don't share caches
            )
            .expect("dp step");
            step_base += 1;
            std::hint::black_box(&params);
        })
        .median_ns
    };

    let serial = run_dp("dp/serial_dense_w4", ReduceMode::Dense, 1);
    run_dp("dp/ring_serial_w4", ReduceMode::Ring, 1);
    let threaded = run_dp(&format!("dp/ring_t{pool}_w4"), ReduceMode::Ring, pool);
    let speedup = serial / threaded.max(1.0);
    println!("dp ring speedup (threaded vs serial dense): {speedup:.2}x on {cores} core(s)");
    statquant::obs::metrics()
        .gauge(
            "dp_ring_speedup",
            "threaded ring dp speedup over serial dense (median, 4 workers)",
        )
        .set(speedup);
    statquant::obs::metrics()
        .gauge("dp_bench_cores", "available_parallelism during dp bench")
        .set(cores as f64);
}
