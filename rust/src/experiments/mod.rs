//! Experiment harness (S15): one module per paper table/figure.
//! See DESIGN.md §8 for the experiment index.

pub mod ablations;
pub mod common;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod table1;
pub mod table2;
pub mod thm1;

use anyhow::Result;

use crate::runtime::{Registry, Runtime};
use crate::util::cli::Args;

/// Dispatch `statquant exp <name> ...`.
pub fn run(name: &str, rt: &Runtime, reg: &Registry, args: &Args) -> Result<()> {
    match name {
        "fig3a" => fig3::fig3a(rt, reg, args),
        "fig3bc" | "fig3b" | "fig3c" => fig3::fig3bc(rt, reg, args),
        "fig4" => fig4::run(rt, reg, args),
        "fig5" => fig5::run(rt, reg, args),
        "table1" => table1::run(rt, reg, args),
        "table2" => table2::run(rt, reg, args),
        "thm1" => thm1::run(rt, reg, args),
        "ablate-bhq-proxy" => ablations::bhq_proxy(rt, reg, args),
        "ablate-bifurcation" => ablations::bifurcation_note(),
        "ablate-allreduce" => ablations::allreduce(rt, reg, args),
        other => anyhow::bail!(
            "unknown experiment {other:?}; known: fig3a fig3bc fig4 fig5 \
             table1 table2 thm1 ablate-bhq-proxy ablate-allreduce"
        ),
    }
}
