//! Figure 5 — machine-translation stand-in (paper §5.4).
//!
//! (a) transformer gradient variance vs bits per quantizer;
//! (b) task quality vs bits (token accuracy / perplexity stand-in for
//!     BLEU; same quantizers + QAT reference).
//!
//! Claims to reproduce: PSQ/BHQ variance << PTQ at equal bits; 5-bit BHQ
//! variance ~ 8-bit PTQ; PTQ diverges at 5 bits while BHQ stays within
//! ~1% of QAT.

use anyhow::Result;

use super::common::{base_config, bits_list, out_dir, warm_params};
use crate::coordinator::trainer::make_dataset;
use crate::coordinator::Trainer;
use crate::metrics::{fmt_sig, CsvWriter, MarkdownTable};
use crate::runtime::{Registry, Runtime, StepKind};
use crate::stats::GradVarianceProbe;
use crate::util::cli::Args;

pub fn run(rt: &Runtime, reg: &Registry, args: &Args) -> Result<()> {
    let mut cfg = base_config(args, reg);
    cfg.model = "transformer".into();
    if args.flag("lr").is_none() {
        cfg.lr = 0.05; // transformer wants a gentler peak LR than the CNN
    }
    let bits = bits_list(args, &[4.0, 5.0, 6.0, 7.0, 8.0]);
    let seeds: usize = args.flag_parse("seeds")?.unwrap_or(8);
    let warm: u64 = args.flag_parse("warm")?.unwrap_or(80);
    let train_bits = match args.flag("train-bits") {
        Some(s) => s
            .split(',')
            .map(|p| p.trim().parse::<f32>().expect("bad --train-bits"))
            .collect(),
        None => vec![5.0, 8.0],
    };
    args.check_unknown()?;

    let dir = out_dir(args);

    // (a) variance vs bits
    let params = warm_params(rt, reg, &cfg, warm)?;
    let meta = reg.meta("transformer", "qat", StepKind::Probe)?;
    let dataset = make_dataset(&cfg, &meta.input_shape, "markov");
    let fixed = dataset.batch(555);
    let mut csv = CsvWriter::create(
        dir.join("fig5a_variance.csv"),
        &["quantizer", "bits", "quant_variance"],
    )?;
    let mut table_a = MarkdownTable::new(&["quantizer", "bits", "Var[quant]"]);
    for q in ["ptq", "psq", "bhq"] {
        let exec = rt.executor(reg.meta("transformer", q, StepKind::Probe)?)?;
        let probe = GradVarianceProbe::new(&exec);
        for &b in &bits {
            let rep = probe.quantization_variance(&params, &fixed.x, &fixed.y, b, seeds, 3)?;
            println!("{q} @ {b}: Var {:.6e}", rep.quant_variance);
            csv.row(&[q.into(), format!("{b}"), format!("{}", rep.quant_variance)])?;
            table_a.row(vec![q.into(), format!("{b}"), fmt_sig(rep.quant_variance, 4)]);
        }
    }
    println!("\n{}", table_a.render());

    // (b) task quality vs bits
    let mut table_b = MarkdownTable::new(&["setting", "eval token acc", "eval loss"]);
    let mut csvb = CsvWriter::create(
        dir.join("fig5b_quality.csv"),
        &["quantizer", "bits", "eval_acc", "eval_loss", "diverged"],
    )?;
    let mut qat_cfg = cfg.clone();
    qat_cfg.variant = "qat".into();
    let rep = Trainer::new(rt, reg, qat_cfg)?.train()?;
    table_b.row(vec![
        "qat".into(),
        format!("{:.4}", rep.final_eval_acc),
        format!("{:.4}", rep.final_eval_loss),
    ]);
    csvb.row(&[
        "qat".into(),
        "32".into(),
        format!("{}", rep.final_eval_acc),
        format!("{}", rep.final_eval_loss),
        "false".into(),
    ])?;
    println!("qat: token acc {:.4}", rep.final_eval_acc);
    for q in ["ptq", "psq", "bhq"] {
        for &b in &train_bits {
            let mut c = cfg.clone();
            c.variant = q.into();
            c.bits = b;
            let rep = Trainer::new(rt, reg, c)?.train()?;
            let tag = format!("{q}@{b}b");
            println!(
                "{tag}: token acc {:.4}{}",
                rep.final_eval_acc,
                if rep.diverged { " DIVERGED" } else { "" }
            );
            table_b.row(vec![
                tag,
                if rep.diverged {
                    "diverge".into()
                } else {
                    format!("{:.4}", rep.final_eval_acc)
                },
                format!("{:.4}", rep.final_eval_loss),
            ]);
            csvb.row(&[
                q.into(),
                format!("{b}"),
                format!("{}", rep.final_eval_acc),
                format!("{}", rep.final_eval_loss),
                format!("{}", rep.diverged),
            ])?;
        }
    }
    println!("\n{}", table_b.render());
    Ok(())
}
