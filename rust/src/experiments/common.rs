//! Shared experiment plumbing.

use anyhow::Result;

use crate::config::TrainConfig;
use crate::coordinator::Trainer;
use crate::runtime::{Registry, Runtime};
use crate::util::cli::Args;

/// Parse "--bits 4,5,6" (default given by caller).
pub fn bits_list(args: &Args, default: &[f32]) -> Vec<f32> {
    match args.flag("bits") {
        None => default.to_vec(),
        Some(s) => s
            .split(',')
            .filter(|p| !p.trim().is_empty())
            .map(|p| p.trim().parse::<f32>().expect("bad --bits"))
            .collect(),
    }
}

pub fn out_dir(args: &Args) -> std::path::PathBuf {
    std::path::PathBuf::from(args.flag("out").unwrap_or("results"))
}

/// Base TrainConfig from common experiment flags.
pub fn base_config(args: &Args, reg: &Registry) -> TrainConfig {
    let mut cfg = TrainConfig {
        artifacts_dir: reg.dir.display().to_string(),
        out_dir: "results/runs".into(),
        ..TrainConfig::default()
    };
    if let Some(m) = args.flag("model") {
        cfg.model = m.into();
    }
    if let Some(s) = args.flag("steps") {
        cfg.steps = s.parse().expect("bad --steps");
    }
    if let Some(l) = args.flag("lr") {
        cfg.lr = l.parse().expect("bad --lr");
    }
    if let Some(s) = args.flag("seed") {
        cfg.seed = s.parse().expect("bad --seed");
    }
    if let Some(o) = args.flag("out") {
        cfg.out_dir = o.into();
    }
    cfg
}

/// Train `model` under QAT for `steps` to get realistically-sparse
/// gradients (the paper probes variance mid-training), returning params.
pub fn warm_params(
    rt: &Runtime,
    reg: &Registry,
    base: &TrainConfig,
    steps: u64,
) -> Result<Vec<f32>> {
    let mut cfg = base.clone();
    cfg.variant = "qat".into();
    cfg.steps = steps;
    cfg.eval_every = steps.max(1);
    let mut tr = Trainer::new(rt, reg, cfg)?;
    let report = tr.train()?;
    eprintln!(
        "[warm] {} steps of QAT -> train loss {:.4}",
        report.steps, report.final_train_loss
    );
    Ok(report.params)
}
