//! Figure 4 — gradient histograms and quantization bin sizes (§5.2).
//!
//! Pipeline: warm the model under QAT, pull the activation gradient at
//! the probe layer via the `actgrad` artifact, then apply each native
//! Rust quantizer at 8 bits and report (i) the histogram of quantized
//! codes (utilization / entropy — PTQ shows the zero spike), (ii) the
//! distribution of per-row bin sizes, and (iii) the quantizer variance
//! Var[Q_b(g) | g] — the quantities the paper's Fig 4 plots.

use anyhow::Result;

use super::common::{base_config, out_dir, warm_params};
use crate::coordinator::trainer::make_dataset;
use crate::metrics::{fmt_sig, CsvWriter, MarkdownTable};
use crate::quant::{GradQuantizer, Mat};
use crate::runtime::{HostTensor, Registry, Runtime, StepKind};
use crate::stats::Histogram;
use crate::util::rng::Pcg32;

use crate::util::cli::Args;

pub fn run(rt: &Runtime, reg: &Registry, args: &Args) -> Result<()> {
    let mut cfg = base_config(args, reg);
    if args.flag("model").is_none() {
        cfg.model = "cnn".into();
    }
    let warm: u64 = args.flag_parse("warm")?.unwrap_or(150);
    let bits: f32 = args.flag_parse("probe-bits")?.unwrap_or(8.0);
    let reps: usize = args.flag_parse("reps")?.unwrap_or(50);
    args.check_unknown()?;

    let params = warm_params(rt, reg, &cfg, warm)?;
    let meta = reg.meta(&cfg.model, "qat", StepKind::ActGrad)?;
    let exec = rt.executor(meta)?;
    let dataset = make_dataset(
        &cfg,
        &meta.input_shape,
        if cfg.model == "transformer" { "markov" } else { "synthimg" },
    );
    let batch = dataset.batch(31_337);
    let inputs = [
        HostTensor::F32(params),
        batch.x,
        batch.y,
        HostTensor::F32(vec![0.0]),
    ];
    let out = exec.run(&inputs)?;
    let flat = out[0].as_f32()?;
    let n = meta.probe_shape[0];
    let d = flat.len() / n;
    let g = Mat::from_vec(n, d, flat.to_vec());

    // Row dynamic ranges — "close to zero for most samples, large for a
    // few outliers" is the paper's empirical premise; print the skew.
    let mut ranges: Vec<f32> = g.row_minmax().iter().map(|&(lo, hi)| hi - lo).collect();
    let mut sorted = ranges.clone();
    sorted.sort_by(f32::total_cmp);
    let med = sorted[n / 2];
    let max = sorted[n - 1];
    println!(
        "activation gradient ({n}x{d}): median row range {:.3e}, max {:.3e}, skew {:.1}x",
        med,
        max,
        max / med.max(1e-30)
    );

    let dir = out_dir(args);
    let mut table = MarkdownTable::new(&[
        "quantizer",
        "Var[Q(g)|g]",
        "bin util",
        "code entropy (bits)",
        "max bin size",
        "median bin size",
    ]);
    let mut rng = Pcg32::new(4242, 0);
    for q in GradQuantizer::PAPER {
        // empirical quantizer variance over `reps` rounding draws
        let mut var = 0.0f64;
        let mut last = None;
        for _ in 0..reps {
            let out = match q {
                GradQuantizer::Ptq => crate::quant::ptq::quantize(&g, crate::quant::nbins(bits), &mut rng),
                GradQuantizer::Psq => crate::quant::psq::quantize(&g, crate::quant::nbins(bits), &mut rng),
                GradQuantizer::Bhq => crate::quant::bhq::quantize(&g, crate::quant::nbins(bits), &mut rng),
                _ => unreachable!(),
            };
            var += out.deq.sq_err(&g);
            last = Some(out);
        }
        var /= reps as f64;
        let qz = last.unwrap();

        let hist = Histogram::from_values(&qz.codes.raw_f32(), 64);
        let mut bins = qz.row_bin_size.clone();
        bins.sort_by(f32::total_cmp);
        let max_bin = bins[bins.len() - 1];
        let med_bin = bins[bins.len() / 2];
        table.row(vec![
            q.name().into(),
            fmt_sig(var, 3),
            format!("{:.3}", hist.utilization()),
            format!("{:.2}", hist.entropy_bits()),
            fmt_sig(f64::from(max_bin), 3),
            fmt_sig(f64::from(med_bin), 3),
        ]);
        std::fs::create_dir_all(&dir)?;
        std::fs::write(
            dir.join(format!("fig4_codes_{}.csv", q.name())),
            hist.to_csv(),
        )?;
        let mut bcsv = CsvWriter::create(
            dir.join(format!("fig4_binsizes_{}.csv", q.name())),
            &["row", "bin_size", "row_range"],
        )?;
        for (i, (&b, &r)) in qz.row_bin_size.iter().zip(&ranges).enumerate() {
            bcsv.rowf(&[i as f64, f64::from(b), f64::from(r)])?;
        }
    }
    // row-range histogram (left panel of Fig 4)
    std::fs::write(
        dir.join("fig4_row_ranges.csv"),
        Histogram::from_values(&ranges, 64).to_csv(),
    )?;
    ranges.sort_by(f32::total_cmp);
    println!("\n{}", table.render());
    println!("csv -> {}/fig4_*.csv", dir.display());
    Ok(())
}
