//! Table 1 — validation accuracy (training loss) across bitwidths (§5.3).
//!
//! Paper: ResNet18/ResNet50 on ImageNet, quantizers {PTQ, PSQ, BHQ} x
//! gradient bits {4..8} + exact + QAT. Here: MiniCNN ("resnet18-proxy")
//! and MiniResNet ("resnet50-proxy") on synthimg (DESIGN.md §4). Shape
//! claims to reproduce: PSQ/BHQ ~ QAT at 8 bits while PTQ lags; the gap
//! grows as bits fall; at 4 bits PTQ diverges while PSQ/BHQ still train;
//! BHQ@5 ~ PTQ@8.

use anyhow::Result;

use super::common::{base_config, bits_list, out_dir};
use crate::coordinator::Trainer;
use crate::metrics::{CsvWriter, MarkdownTable};
use crate::runtime::{Registry, Runtime};
use crate::util::cli::Args;

pub fn run(rt: &Runtime, reg: &Registry, args: &Args) -> Result<()> {
    let cfg0 = base_config(args, reg);
    let models: Vec<String> = args
        .flag("models")
        .map(|s| s.split(',').map(String::from).collect())
        .unwrap_or_else(|| vec!["cnn".into(), "resnet".into()]);
    let bits = bits_list(args, &[4.0, 5.0, 6.0, 7.0, 8.0]);
    let quants = ["ptq", "psq", "bhq"];
    args.check_unknown()?;

    let dir = out_dir(args);
    let mut csv = CsvWriter::create(
        dir.join("table1.csv"),
        &["model", "setting", "quantizer", "bits", "eval_acc", "train_loss", "diverged"],
    )?;

    for model in &models {
        let mut table = MarkdownTable::new(&["Setting", "PTQ", "PSQ", "BHQ"]);
        println!("=== Table 1: {model} (proxy) ===");

        let mut run_one = |variant: &str, b: f32| -> Result<(String, f64, bool)> {
            let mut c = cfg0.clone();
            c.model = model.clone();
            c.variant = variant.into();
            c.bits = b;
            let rep = Trainer::new(rt, reg, c)?.train()?;
            let cell = if rep.diverged {
                "diverge".to_string()
            } else {
                format!("{:.2} ({:.3})", 100.0 * rep.final_eval_acc, rep.final_train_loss)
            };
            println!("  {variant}@{b}: {cell}");
            Ok((cell, rep.final_eval_acc, rep.diverged))
        };

        // Exact + QAT rows (bits column irrelevant).
        for v in ["exact", "qat"] {
            let (cell, acc, div) = run_one(v, 8.0)?;
            table.row(vec![v.into(), cell, "—".into(), "—".into()]);
            csv.row(&[
                model.clone(),
                v.into(),
                v.into(),
                "32".into(),
                format!("{acc}"),
                "".into(),
                format!("{div}"),
            ])?;
        }

        for &b in &bits {
            let mut cells = vec![format!("{}-bit FQT", b as u32)];
            for q in quants {
                let (cell, acc, div) = run_one(q, b)?;
                cells.push(cell);
                csv.row(&[
                    model.clone(),
                    format!("{}-bit", b as u32),
                    q.into(),
                    format!("{b}"),
                    format!("{acc}"),
                    "".into(),
                    format!("{div}"),
                ])?;
            }
            table.row(cells);
        }
        let rendered = table.render();
        println!("\n{rendered}");
        std::fs::write(dir.join(format!("table1_{model}.md")), rendered)?;
    }
    println!("csv -> {}", dir.join("table1.csv").display());
    Ok(())
}
