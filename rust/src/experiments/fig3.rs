//! Figure 3 — CIFAR10 convergence & variance (paper §5.1).
//!
//! (a) gradient variance vs bitwidth per quantizer, against the QAT
//!     (subsampling) variance reference;
//! (b)/(c) convergence curves and final accuracy vs bitwidth.
//!
//! Paper's claims to reproduce (shape, not absolute numbers):
//!   * each fewer bit ~4x the quantization variance;
//!   * BHQ ~ PTQ with ~3 fewer bits;
//!   * accuracy degrades once quantization variance exceeds ~10% of the
//!     QAT variance; PTQ below 6 bits decays/diverges first.

use anyhow::Result;

use super::common::{base_config, bits_list, out_dir, warm_params};
use crate::coordinator::Trainer;
use crate::metrics::{fmt_sig, CsvWriter, MarkdownTable};
use crate::runtime::{Registry, Runtime, StepKind};
use crate::stats::GradVarianceProbe;
use crate::coordinator::trainer::make_dataset;
use crate::util::cli::Args;

pub fn fig3a(rt: &Runtime, reg: &Registry, args: &Args) -> Result<()> {
    let mut cfg = base_config(args, reg);
    if args.flag("model").is_none() {
        cfg.model = "cnn".into();
    }
    let bits = bits_list(args, &[2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
    let seeds: usize = args.flag_parse("seeds")?.unwrap_or(12);
    let warm: u64 = args.flag_parse("warm")?.unwrap_or(100);
    let quants: Vec<&str> = args
        .flag("quant")
        .map(|s| s.split(',').collect())
        .unwrap_or_else(|| vec!["ptq", "psq", "bhq"]);
    args.check_unknown()?;

    let params = warm_params(rt, reg, &cfg, warm)?;
    let meta = reg.meta(&cfg.model, "qat", StepKind::Probe)?;
    let dataset = make_dataset(&cfg, &meta.input_shape, if cfg.model == "transformer" { "markov" } else { "synthimg" });

    // QAT subsampling variance (the Fig-3a horizontal reference line).
    let qat_exec = rt.executor(meta)?;
    let qat_probe = GradVarianceProbe::new(&qat_exec);
    let batches: Vec<_> = (0..seeds as u64)
        .map(|i| {
            let b = dataset.batch(10_000 + i);
            (b.x, b.y)
        })
        .collect();
    let qat_var = qat_probe.batch_variance(&params, &batches, 8.0)?;
    println!(
        "QAT (subsampling) variance: {:.6e}  ||E g||^2 = {:.6e}",
        qat_var.quant_variance, qat_var.mean_sq_norm
    );

    let dir = out_dir(args);
    let mut csv = CsvWriter::create(
        dir.join(format!("fig3a_{}.csv", cfg.model)),
        &["quantizer", "bits", "quant_variance", "qat_variance", "ratio"],
    )?;
    let mut table = MarkdownTable::new(&["quantizer", "bits", "Var[quant]", "Var/Var_QAT"]);

    let fixed = dataset.batch(424_242);
    for q in &quants {
        let meta = reg.meta(&cfg.model, q, StepKind::Probe)?;
        let exec = rt.executor(meta)?;
        let probe = GradVarianceProbe::new(&exec);
        for &b in &bits {
            let rep = probe.quantization_variance(&params, &fixed.x, &fixed.y, b, seeds, 7)?;
            let ratio = rep.quant_variance / qat_var.quant_variance.max(1e-30);
            println!(
                "{q} @ {b} bits: Var_quant = {:.6e} ({}x QAT)",
                rep.quant_variance,
                fmt_sig(ratio, 3)
            );
            csv.rowf(&[0.0, f64::from(b), rep.quant_variance, qat_var.quant_variance, ratio])?;
            table.row(vec![
                q.to_string(),
                format!("{b}"),
                fmt_sig(rep.quant_variance, 4),
                fmt_sig(ratio, 3),
            ]);
        }
    }
    println!("\n{}", table.render());
    println!("csv -> {}", dir.join(format!("fig3a_{}.csv", cfg.model)).display());
    Ok(())
}

pub fn fig3bc(rt: &Runtime, reg: &Registry, args: &Args) -> Result<()> {
    let mut cfg = base_config(args, reg);
    if args.flag("model").is_none() {
        cfg.model = "cnn".into();
    }
    let bits = bits_list(args, &[4.0, 5.0, 6.0, 7.0, 8.0]);
    let quants: Vec<String> = args
        .flag("quant")
        .map(|s| s.split(',').map(String::from).collect())
        .unwrap_or_else(|| vec!["ptq".into(), "psq".into(), "bhq".into()]);
    args.check_unknown()?;

    let dir = out_dir(args);
    let mut table = MarkdownTable::new(&["setting", "eval acc", "train loss", "steps/s"]);
    let mut csv = CsvWriter::create(
        dir.join(format!("fig3c_{}.csv", cfg.model)),
        &["quantizer", "bits", "eval_acc", "train_loss", "diverged"],
    )?;

    // Baselines: exact + QAT.
    for v in ["exact", "qat"] {
        let mut c = cfg.clone();
        c.variant = v.into();
        let rep = Trainer::new(rt, reg, c)?.train()?;
        table.row(vec![
            v.into(),
            format!("{:.4}", rep.final_eval_acc),
            format!("{:.4}", rep.final_train_loss),
            format!("{:.2}", rep.steps_per_second),
        ]);
        csv.row(&[
            v.into(),
            "32".into(),
            format!("{}", rep.final_eval_acc),
            format!("{}", rep.final_train_loss),
            format!("{}", rep.diverged),
        ])?;
        println!("{v}: acc {:.4} loss {:.4}", rep.final_eval_acc, rep.final_train_loss);
    }

    for q in &quants {
        for &b in &bits {
            let mut c = cfg.clone();
            c.variant = q.clone();
            c.bits = b;
            let rep = Trainer::new(rt, reg, c)?.train()?;
            let tag = format!("{q}@{b}b");
            table.row(vec![
                tag.clone(),
                if rep.diverged {
                    "diverge".into()
                } else {
                    format!("{:.4}", rep.final_eval_acc)
                },
                format!("{:.4}", rep.final_train_loss),
                format!("{:.2}", rep.steps_per_second),
            ]);
            csv.row(&[
                q.clone(),
                format!("{b}"),
                format!("{}", rep.final_eval_acc),
                format!("{}", rep.final_train_loss),
                format!("{}", rep.diverged),
            ])?;
            println!(
                "{tag}: acc {:.4} loss {:.4}{}",
                rep.final_eval_acc,
                rep.final_train_loss,
                if rep.diverged { " DIVERGED" } else { "" }
            );
        }
    }
    println!("\n{}", table.render());
    Ok(())
}
