//! Table 2 — 8-bit training formats, end-to-end (§5.3).
//!
//! The paper cites external systems (FP8, HBFP8, HFP8, WAGEUBN, Unified
//! INT8); per DESIGN.md §4 we re-implement the *formats* as gradient
//! quantizers (fp8-sim E4M3, block floating point) and compare all five
//! under identical training — the honest analogue of a citation table.
//! Shape claim: BHQ >= PSQ >= {PTQ, FP8, BFP} at the 8-bit budget.

use anyhow::Result;

use super::common::{base_config, out_dir};
use crate::coordinator::Trainer;
use crate::metrics::{CsvWriter, MarkdownTable};
use crate::runtime::{Registry, Runtime};
use crate::util::cli::Args;

pub fn run(rt: &Runtime, reg: &Registry, args: &Args) -> Result<()> {
    let mut cfg0 = base_config(args, reg);
    if args.flag("model").is_none() {
        cfg0.model = "cnn".into(); // extension formats are built for cnn
    }
    let bits: f32 = args.flag_parse("table2-bits")?.unwrap_or(8.0);
    args.check_unknown()?;

    let dir = out_dir(args);
    let mut table = MarkdownTable::new(&["Method", "Val. acc (%)", "Train loss"]);
    let mut csv = CsvWriter::create(
        dir.join("table2.csv"),
        &["method", "eval_acc", "train_loss", "diverged"],
    )?;

    let mut run_one = |variant: &str| -> Result<()> {
        let mut c = cfg0.clone();
        c.variant = variant.into();
        c.bits = bits;
        let rep = Trainer::new(rt, reg, c)?.train()?;
        let label = match variant {
            "fp8" => "FP8-sim (E4M3) [24-like]",
            "bfp" => "BFP (HBFP-like) [26-like]",
            "ptq" => "INT8 PTQ [20/22-like]",
            "psq" => "PSQ (ours)",
            "bhq" => "BHQ (ours)",
            other => other,
        };
        println!(
            "{label}: acc {:.2}% loss {:.4}{}",
            100.0 * rep.final_eval_acc,
            rep.final_train_loss,
            if rep.diverged { " DIVERGED" } else { "" }
        );
        table.row(vec![
            label.into(),
            if rep.diverged {
                "diverge".into()
            } else {
                format!("{:.2}", 100.0 * rep.final_eval_acc)
            },
            format!("{:.4}", rep.final_train_loss),
        ]);
        csv.row(&[
            variant.into(),
            format!("{}", rep.final_eval_acc),
            format!("{}", rep.final_train_loss),
            format!("{}", rep.diverged),
        ])?;
        Ok(())
    };

    // QAT upper reference, then the five formats.
    run_one("qat")?;
    for v in ["fp8", "bfp", "ptq", "psq", "bhq"] {
        run_one(v)?;
    }
    let rendered = table.render();
    println!("\n{rendered}");
    std::fs::write(dir.join("table2.md"), rendered)?;
    Ok(())
}
