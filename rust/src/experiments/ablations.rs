//! Ablations called out in DESIGN.md §8.

use anyhow::Result;

use super::common::{base_config, out_dir, warm_params};
use crate::coordinator::trainer::make_dataset;
use crate::coordinator::{DataParallel, ReduceMode, Schedule};
use crate::metrics::{fmt_sig, CsvWriter, MarkdownTable};
use crate::quant::bhq::{self, Proxy};
use crate::quant::{GradQuantizer, Mat};
use crate::runtime::{HostTensor, Registry, Runtime, StepKind};
use crate::util::cli::Args;
use crate::util::rng::Pcg32;

/// BHQ group-count proxy: Appendix D.5 as printed ("paper") vs the full
/// D.4 bound ("extended"). Measured as empirical quantizer variance on
/// (i) synthetic k-outlier matrices and (ii) the model's real activation
/// gradient from the actgrad artifact.
pub fn bhq_proxy(rt: &Runtime, reg: &Registry, args: &Args) -> Result<()> {
    let mut cfg = base_config(args, reg);
    if args.flag("model").is_none() {
        cfg.model = "cnn".into();
    }
    let reps: usize = args.flag_parse("reps")?.unwrap_or(100);
    let bits: f32 = args.flag_parse("probe-bits")?.unwrap_or(4.0);
    args.check_unknown()?;
    let nb = crate::quant::nbins(bits);

    let mut table = MarkdownTable::new(&[
        "input",
        "G(paper)",
        "G(ext)",
        "Var paper-proxy",
        "Var extended",
        "ext/paper",
    ]);
    let mut eval = |name: String, x: &Mat| {
        let plan_p = bhq::build_plan_with(x, Proxy::Paper);
        let plan_e = bhq::build_plan_with(x, Proxy::Extended);
        let mut var = |proxy: Proxy| {
            let mut rng = Pcg32::new(7, 7);
            let mut acc = 0.0;
            for _ in 0..reps {
                acc += bhq::quantize_with(x, nb, &mut rng, proxy).deq.sq_err(x);
            }
            acc / reps as f64
        };
        let vp = var(Proxy::Paper);
        let ve = var(Proxy::Extended);
        table.row(vec![
            name,
            format!("{}", plan_p.n_groups),
            format!("{}", plan_e.n_groups),
            fmt_sig(vp, 3),
            fmt_sig(ve, 3),
            format!("{:.3}", ve / vp.max(1e-30)),
        ]);
    };

    // synthetic k-outlier matrices
    for k in [1usize, 2, 4, 8] {
        let mut rng = Pcg32::new(k as u64, 1);
        let mut x = Mat::zeros(32, 64);
        for i in 0..32 {
            let s = if i < k { 10.0 } else { 0.01 };
            for v in x.row_mut(i) {
                *v = rng.normal() * s;
            }
        }
        eval(format!("synthetic {k}-outlier"), &x);
    }

    // the real activation gradient
    let params = warm_params(rt, reg, &cfg, 100)?;
    let meta = reg.meta(&cfg.model, "qat", StepKind::ActGrad)?;
    let exec = rt.executor(meta)?;
    let dataset = make_dataset(&cfg, &meta.input_shape, "synthimg");
    let b = dataset.batch(2024);
    let out = exec.run(&[
        HostTensor::F32(params),
        b.x,
        b.y,
        HostTensor::F32(vec![0.0]),
    ])?;
    let flat = out[0].as_f32()?;
    let n = meta.probe_shape[0];
    let g = Mat::from_vec(n, flat.len() / n, flat.to_vec());
    eval(format!("{} actgrad", cfg.model), &g);

    println!("{}", table.render());
    std::fs::create_dir_all(out_dir(args))?;
    std::fs::write(out_dir(args).join("ablate_bhq_proxy.md"), table.render())?;
    Ok(())
}

/// Gradient bifurcation ablation note: Q_b1 (the weight-gradient
/// quantizer) is fixed at 8-bit stochastic PTQ in every artifact, as in
/// the paper's Appendix E; the `ptq_nb1` aot variant (Q_b1 = identity,
/// i.e. Banner et al.'s original setting) can be added to
/// `python/compile/aot.py::artifact_plan` to ablate it end to end.
pub fn bifurcation_note() -> Result<()> {
    println!(
        "bifurcation ablation: Q_b1 is 8-bit stochastic PTQ in all artifacts \
         (paper Appendix E). Compare against `variant=qat` (Q_b1 = Q_b2 = id) \
         via `exp fig3a --quant qat,ptq` for the no-quantization reference."
    );
    Ok(())
}

/// Data-parallel quantized all-reduce: convergence vs all-reduce bits,
/// dense vs ring. Dense quantizes the (W, P) matrix per-row — PSQ/BHQ
/// across *workers*; ring quantizes per-(worker, segment) payloads with
/// triple-keyed SR seeds (DESIGN.md S12). The serial-vs-ring comparison
/// in EXPERIMENTS.md comes from this table.
pub fn allreduce(rt: &Runtime, reg: &Registry, args: &Args) -> Result<()> {
    let mut cfg = base_config(args, reg);
    if args.flag("model").is_none() {
        cfg.model = "mlp".into();
    }
    let workers: usize = args.flag_parse("workers")?.unwrap_or(4);
    let steps: u64 = args.flag_parse("dp-steps")?.unwrap_or(150);
    let threads: usize = args.flag_parse("dp-threads")?.unwrap_or(1);
    let quant = args.flag("quant").unwrap_or("psq");
    let q = GradQuantizer::from_name(quant)
        .ok_or_else(|| anyhow::anyhow!("unknown quantizer {quant}"))?;
    args.check_unknown()?;

    let meta = reg.meta(&cfg.model, "qat", StepKind::Probe)?;
    let exec = rt.executor(meta)?;
    let dataset = make_dataset(&cfg, &meta.input_shape, "synthimg");

    let dir = out_dir(args);
    let mut csv = CsvWriter::create(
        dir.join("ablate_allreduce.csv"),
        &["mode", "allreduce_bits", "final_loss", "mean_last10"],
    )?;
    let mut table = MarkdownTable::new(&["mode", "all-reduce", "final loss", "mean(last 10)"]);
    for mode in [ReduceMode::Dense, ReduceMode::Ring] {
        for bits in [0.0f32, 4.0, 6.0, 8.0] {
            let dp = DataParallel {
                probe: &exec,
                workers,
                allreduce_bits: bits,
                quantizer: q,
                momentum: 0.9,
                threads: if mode == ReduceMode::Ring { threads } else { 1 },
                mode,
            };
            let mut params = reg.init_params(&cfg.model)?;
            let hist = dp.train(
                dataset.as_ref(),
                &mut params,
                steps,
                cfg.lr,
                Schedule::Cosine,
                steps / 20,
                8.0,
                cfg.seed,
            )?;
            let final_loss = hist.last().map(|s| s.loss).unwrap_or(f64::NAN);
            let tail: Vec<f64> = hist.iter().rev().take(10).map(|s| s.loss).collect();
            let mean_tail = tail.iter().sum::<f64>() / tail.len().max(1) as f64;
            let label = if bits == 0.0 {
                "fp32".to_string()
            } else {
                format!("{quant}@{bits}b")
            };
            println!(
                "{} {label}: final loss {final_loss:.4}, tail mean {mean_tail:.4}",
                mode.name()
            );
            table.row(vec![
                mode.name().into(),
                label,
                format!("{final_loss:.4}"),
                format!("{mean_tail:.4}"),
            ]);
            csv.row(&[
                mode.name().to_string(),
                format!("{bits}"),
                format!("{final_loss}"),
                format!("{mean_tail}"),
            ])?;
        }
    }
    println!("\n{}", table.render());
    std::fs::write(dir.join("ablate_allreduce.md"), table.render())?;
    Ok(())
}
