//! Theorem 1 / Eq. 10 statistical validation (DESIGN.md E10/E11).
//!
//! (1) Unbiasedness: E[FQT grad | batch] must equal the QAT gradient.
//!     We average K probe draws per quantizer and report the max
//!     z-score against the Monte-Carlo standard error and the cosine
//!     similarity — an end-to-end check through the real model graph.
//! (2) The 4x-per-bit law: fit the slope of log2 Var vs bits; Theorem 2 +
//!     Eq. 9 predict slope ~ -2 (each fewer bit quadruples variance).

use anyhow::Result;

use super::common::{base_config, bits_list, warm_params};
use crate::coordinator::trainer::make_dataset;
use crate::metrics::MarkdownTable;
use crate::runtime::{Registry, Runtime, StepKind};
use crate::stats::GradVarianceProbe;
use crate::util::cli::Args;

pub fn run(rt: &Runtime, reg: &Registry, args: &Args) -> Result<()> {
    let mut cfg = base_config(args, reg);
    if args.flag("model").is_none() {
        cfg.model = "mlp".into();
    }
    let seeds: usize = args.flag_parse("seeds")?.unwrap_or(64);
    let warm: u64 = args.flag_parse("warm")?.unwrap_or(60);
    let bits_fit = bits_list(args, &[3.0, 4.0, 5.0, 6.0, 7.0]);
    args.check_unknown()?;

    let params = warm_params(rt, reg, &cfg, warm)?;
    let meta = reg.meta(&cfg.model, "qat", StepKind::Probe)?;
    let dataset = make_dataset(&cfg, &meta.input_shape, "synthimg");
    let fixed = dataset.batch(999);

    // QAT reference gradient (deterministic given the batch).
    let qat_exec = rt.executor(meta)?;
    let qat = GradVarianceProbe::new(&qat_exec);
    let (g_ref, _) = qat.mean_gradient(&params, &fixed.x, &fixed.y, 8.0, 1, 0)?;

    let mut table = MarkdownTable::new(&[
        "quantizer",
        "bits",
        "max |z|",
        "cosine(E[g_fqt], g_qat)",
        "verdict",
    ]);
    for q in ["ptq", "psq", "bhq"] {
        let exec = rt.executor(reg.meta(&cfg.model, q, StepKind::Probe)?)?;
        let probe = GradVarianceProbe::new(&exec);
        for &b in &[4.0f32, 6.0] {
            let (mean, coord_var) =
                probe.mean_gradient(&params, &fixed.x, &fixed.y, b, seeds, 11)?;
            // exact per-coordinate z-scores (floor tiny SEs: coordinates
            // reproduced deterministically have var 0 up to f32 noise)
            let gnorm: f64 =
                (g_ref.iter().map(|&v| v * v).sum::<f64>() / g_ref.len() as f64).sqrt();
            let max_z = mean
                .iter()
                .zip(&g_ref)
                .zip(&coord_var)
                .map(|((&m, &r), &v)| {
                    let se = (v / seeds as f64).sqrt().max(1e-6 * gnorm);
                    (m - r).abs() / se
                })
                .fold(0.0f64, f64::max);
            let dot: f64 = mean.iter().zip(&g_ref).map(|(&a, &b)| a * b).sum();
            let na: f64 = mean.iter().map(|&a| a * a).sum::<f64>().sqrt();
            let nb: f64 = g_ref.iter().map(|&a| a * a).sum::<f64>().sqrt();
            let cos = dot / (na * nb).max(1e-30);
            // max over P coordinates of |N(0,1)| concentrates ~ sqrt(2 ln P) ~ 4.5;
            // 8 is a generous unbiasedness acceptance threshold.
            let ok = max_z < 8.0 && cos > 0.99;
            println!(
                "{q}@{b}: max|z| = {max_z:.2}, cos = {cos:.5} -> {}",
                if ok { "UNBIASED" } else { "SUSPECT" }
            );
            table.row(vec![
                q.into(),
                format!("{b}"),
                format!("{max_z:.2}"),
                format!("{cos:.5}"),
                if ok { "unbiased ✓".into() } else { "SUSPECT".into() },
            ]);
        }
    }
    println!("\n{}", table.render());

    // (2) 4x law: slope of log2(Var) vs bits for PTQ.
    let exec = rt.executor(reg.meta(&cfg.model, "ptq", StepKind::Probe)?)?;
    let probe = GradVarianceProbe::new(&exec);
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    println!("\n4x-per-bit law (PTQ):");
    for &b in &bits_fit {
        let rep = probe.quantization_variance(&params, &fixed.x, &fixed.y, b, seeds.min(24), 21)?;
        println!("  {b} bits: Var = {:.6e}", rep.quant_variance);
        xs.push(f64::from(b));
        ys.push(rep.quant_variance.max(1e-300).log2());
    }
    let n = xs.len() as f64;
    let sx: f64 = xs.iter().sum();
    let sy: f64 = ys.iter().sum();
    let sxx: f64 = xs.iter().map(|x| x * x).sum();
    let sxy: f64 = xs.iter().zip(&ys).map(|(x, y)| x * y).sum();
    let slope = (n * sxy - sx * sy) / (n * sxx - sx * sx);
    println!(
        "slope d log2(Var) / d bits = {slope:.3}  (theory: -2.0, i.e. 4x per bit)"
    );
    Ok(())
}
