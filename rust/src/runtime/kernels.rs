//! Cache-blocked f32 compute kernels for the native executor.
//!
//! Row-major, batched GEMM / GEMM-transpose primitives plus the fused
//! epilogues the MLP interpreter needs (bias-init, relu, relu-mask,
//! column sums). The blocked `gemm` replaces the per-sample triple loops
//! that used to live in `native.rs`; `naive` retains the reference
//! formulation for the golden-parity harness (`tests/kernel_parity.rs`).
//!
//! ## Tiling scheme
//!
//! `gemm` computes `C (m x n) = init + A (m x k) · B (k x n)` as an
//! axpy-style kernel: the K axis is split into [`KC`]-wide tiles (so the
//! active B panel stays cache-resident across the whole row sweep), and
//! rows of C are processed [`MR`] at a time so each B row loaded from
//! cache is reused against `MR` accumulator rows. The inner loop is a
//! column panel (`c[j] += a_ik * b[k][j]` over contiguous `j`) with no
//! horizontal reductions — exactly the shape LLVM's autovectorizer turns
//! into SIMD fma-free lanes.
//!
//! ## Determinism contract (load-bearing)
//!
//! Every output element is produced by a *single* accumulator whose
//! additions happen in ascending-k order (for [`gemm`]) or ascending-m
//! order (for [`gemm_at_b`]), starting from the init value — the same
//! per-element operation sequence as the naive triple loop. Rust f32
//! `a * b + c` lowers to separate IEEE-754 mul and add (never contracted
//! to fma), and vector lanes are element-independent, so the blocked
//! kernels are **bitwise identical** to `naive` regardless of
//! autovectorization. The parity tests assert this; if a future change
//! reassociates an accumulation (e.g. split-K with a reduction tree), it
//! must widen those tests to a tolerance band and update DESIGN.md §5.
//!
//! Zero multipliers are never skipped: `0.0 * inf = NaN` and the
//! quantizers' poison contract depends on NaN propagating through the
//! backward matmuls.

/// K-tile width: `KC * n * 4` bytes of B panel kept hot (for the MLP
/// geometries n is tens of columns, so the panel is well under L1).
pub const KC: usize = 128;

/// Rows of C processed together so one B row load feeds MR accumulator
/// rows held in registers.
pub const MR: usize = 4;

/// How the C buffer is seeded before accumulation.
#[derive(Clone, Copy, Debug)]
pub enum Init<'a> {
    /// `C = 0` before accumulation.
    Zero,
    /// Every row of C starts as this bias vector (len n) — the fused
    /// bias-add epilogue, applied as *initialization* so the add order
    /// matches `bias + sum_k(..)` exactly.
    Bias(&'a [f32]),
}

fn apply_init(c: &mut [f32], init: Init<'_>, n: usize) {
    match init {
        Init::Zero => c.fill(0.0),
        Init::Bias(bias) => {
            assert_eq!(bias.len(), n, "gemm: bias length != n");
            for row in c.chunks_exact_mut(n) {
                row.copy_from_slice(bias);
            }
        }
    }
}

/// One C row accumulating one scaled B row: `c[j] += a * b[j]`.
#[inline]
fn axpy(c: &mut [f32], a: f32, b: &[f32]) {
    for (cv, &bv) in c.iter_mut().zip(b) {
        *cv += a * bv;
    }
}

/// Four C rows accumulating the same B row (the MR = 4 micro-kernel).
/// Each lane touches a distinct output element, so the per-element
/// operation order is identical to four sequential `axpy` calls.
#[inline]
fn axpy4(
    c0: &mut [f32],
    c1: &mut [f32],
    c2: &mut [f32],
    c3: &mut [f32],
    a: [f32; 4],
    b: &[f32],
) {
    for ((((x0, x1), x2), x3), &bv) in c0
        .iter_mut()
        .zip(c1.iter_mut())
        .zip(c2.iter_mut())
        .zip(c3.iter_mut())
        .zip(b)
    {
        *x0 += a[0] * bv;
        *x1 += a[1] * bv;
        *x2 += a[2] * bv;
        *x3 += a[3] * bv;
    }
}

/// One C row accumulating four (scalar, B row) pairs in ascending sample
/// order — the [`gemm_at_b`] micro-kernel. The adds chain through one
/// accumulator per element, preserving the m-ascending order.
#[inline]
fn axpy_m4(c: &mut [f32], a: [f32; 4], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32]) {
    for ((((cv, &v0), &v1), &v2), &v3) in c.iter_mut().zip(b0).zip(b1).zip(b2).zip(b3) {
        let mut acc = *cv;
        acc += a[0] * v0;
        acc += a[1] * v1;
        acc += a[2] * v2;
        acc += a[3] * v3;
        *cv = acc;
    }
}

/// Blocked `C (m x n) = init + A (m x k) · B (k x n)`, all row-major.
pub fn gemm(c: &mut [f32], init: Init<'_>, a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k, "gemm: A is not m x k");
    assert_eq!(b.len(), k * n, "gemm: B is not k x n");
    assert_eq!(c.len(), m * n, "gemm: C is not m x n");
    if m == 0 || n == 0 {
        return;
    }
    apply_init(c, init, n);
    let mut k0 = 0;
    while k0 < k {
        let k1 = (k0 + KC).min(k);
        let mut i = 0;
        while i + MR <= m {
            let rows = &mut c[i * n..(i + MR) * n];
            let (c0, rest) = rows.split_at_mut(n);
            let (c1, rest) = rest.split_at_mut(n);
            let (c2, c3) = rest.split_at_mut(n);
            for kk in k0..k1 {
                let scal = [
                    a[i * k + kk],
                    a[(i + 1) * k + kk],
                    a[(i + 2) * k + kk],
                    a[(i + 3) * k + kk],
                ];
                axpy4(c0, c1, c2, c3, scal, &b[kk * n..(kk + 1) * n]);
            }
            i += MR;
        }
        while i < m {
            let crow = &mut c[i * n..(i + 1) * n];
            for kk in k0..k1 {
                axpy(crow, a[i * k + kk], &b[kk * n..(kk + 1) * n]);
            }
            i += 1;
        }
        k0 = k1;
    }
}

/// Blocked `C (k x n) = init + Aᵀ · B` for row-major `A (m x k)` and
/// `B (m x n)` — the weight-gradient contraction over the batch axis.
/// Samples are consumed in ascending order (four at a time), so each
/// output element accumulates in the same order as the naive loop.
pub fn gemm_at_b(
    c: &mut [f32],
    init: Init<'_>,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
) {
    assert_eq!(a.len(), m * k, "gemm_at_b: A is not m x k");
    assert_eq!(b.len(), m * n, "gemm_at_b: B is not m x n");
    assert_eq!(c.len(), k * n, "gemm_at_b: C is not k x n");
    if k == 0 || n == 0 {
        return;
    }
    apply_init(c, init, n);
    let mut mi = 0;
    while mi + 4 <= m {
        let a0 = &a[mi * k..(mi + 1) * k];
        let a1 = &a[(mi + 1) * k..(mi + 2) * k];
        let a2 = &a[(mi + 2) * k..(mi + 3) * k];
        let a3 = &a[(mi + 3) * k..(mi + 4) * k];
        let b0 = &b[mi * n..(mi + 1) * n];
        let b1 = &b[(mi + 1) * n..(mi + 2) * n];
        let b2 = &b[(mi + 2) * n..(mi + 3) * n];
        let b3 = &b[(mi + 3) * n..(mi + 4) * n];
        for (kk, crow) in c.chunks_exact_mut(n).enumerate() {
            axpy_m4(crow, [a0[kk], a1[kk], a2[kk], a3[kk]], b0, b1, b2, b3);
        }
        mi += 4;
    }
    while mi < m {
        let ai = &a[mi * k..(mi + 1) * k];
        let bi = &b[mi * n..(mi + 1) * n];
        for (kk, crow) in c.chunks_exact_mut(n).enumerate() {
            axpy(crow, ai[kk], bi);
        }
        mi += 1;
    }
}

/// `dst (cols x rows) = srcᵀ` for row-major `src (rows x cols)`.
pub fn transpose(dst: &mut [f32], src: &[f32], rows: usize, cols: usize) {
    assert_eq!(src.len(), rows * cols, "transpose: src shape");
    assert_eq!(dst.len(), rows * cols, "transpose: dst shape");
    if rows == 0 || cols == 0 {
        return;
    }
    for (i, row) in src.chunks_exact(cols).enumerate() {
        for (j, &v) in row.iter().enumerate() {
            dst[j * rows + i] = v;
        }
    }
}

/// Elementwise `dst = max(src, 0)`.
pub fn relu(dst: &mut [f32], src: &[f32]) {
    assert_eq!(dst.len(), src.len(), "relu: shape mismatch");
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = s.max(0.0);
    }
}

/// Zero `g` wherever the pre-activation was non-positive (the backward
/// relu mask; `<= 0.0` matches the forward `max(0.0)` subgradient).
pub fn relu_mask(g: &mut [f32], pre: &[f32]) {
    assert_eq!(g.len(), pre.len(), "relu_mask: shape mismatch");
    for (v, &p) in g.iter_mut().zip(pre) {
        if p <= 0.0 {
            *v = 0.0;
        }
    }
}

/// `out (n) = column sums of a (rows x n)`, rows consumed in ascending
/// order — the bias-gradient reduction.
pub fn col_sums(out: &mut [f32], a: &[f32], n: usize) {
    assert_eq!(out.len(), n, "col_sums: out length != n");
    out.fill(0.0);
    if n == 0 {
        return;
    }
    assert_eq!(a.len() % n, 0, "col_sums: A not a multiple of n");
    for row in a.chunks_exact(n) {
        for (o, &v) in out.iter_mut().zip(row) {
            *o += v;
        }
    }
}

// ---------------------------------------------------------------------
// Integer-code kernels (the true low-bitwidth backward path)
// ---------------------------------------------------------------------
//
// `gemm_i8` / `gemm_i8_at_b` consume centered i8 codes (see
// `quant::codes`) instead of dequantized f32. Both pack their operands
// into K-padded, pre-widened i16 panels — i16 inputs let the 4-lane i32
// dot product lower to multiply-accumulate SIMD (pmaddwd-class) where a
// raw i8 formulation does not — accumulate in i32 (exact: centered
// products are <= 128*128, so any K < 2^17 fits), and fold the affine
// reconstruction in a fused epilogue:
//
//   A[i,k] = ca[i,k]*inv_a_i + zero_a_i,  B likewise =>
//   C[i,j] = init
//          + inv_a_i*inv_b_j * S_ij          (S = integer code GEMM)
//          + inv_a_i*zero_b_j * rowsum_ca[i]
//          + zero_a_i*inv_b_j * rowsum_cb[j]
//          + zero_a_i*zero_b_j * K.
//
// The code sums come out of the packing pass; zero padding is exact
// because centered pad codes contribute 0 to both sums and products.
// Integer accumulation is associative, so the blocked kernels are
// bitwise identical to `naive::{gemm_i8,gemm_i8_at_b}` by construction;
// the f32 epilogue keeps determinism the same way the f32 kernels do
// (one accumulator chain per element, fixed order, no fma). NaN poison
// flows through the *scales* (i8 codes cannot carry NaN): a poisoned
// row/tensor has NaN inv/zero, which the epilogue spreads across the
// affected outputs.

/// Round a contraction length up to the i16 panel granularity (SIMD
/// lane multiple; zero-padded, which is exact for centered codes).
pub fn padded_k(k: usize) -> usize {
    (k + 15) & !15
}

/// Reusable panel/sum/accumulator buffers for the integer kernels:
/// resize-never-shrink, one per executor workspace, so the int8 step
/// stays allocation-free after warm-up.
#[derive(Default)]
pub struct IntGemmScratch {
    pa: Vec<i16>,
    pb: Vec<i16>,
    sums_a: Vec<i32>,
    sums_b: Vec<i32>,
    acc: Vec<i32>,
}

impl IntGemmScratch {
    /// Currently reserved bytes (for the workspace high-water gauge).
    pub fn bytes(&self) -> usize {
        2 * (self.pa.capacity() + self.pb.capacity())
            + 4 * (self.sums_a.capacity() + self.sums_b.capacity() + self.acc.capacity())
    }
}

/// Per-row or per-tensor scale lookup (len 1 = per-tensor).
#[inline]
fn sel(s: &[f32], i: usize) -> f32 {
    if s.len() == 1 {
        s[0]
    } else {
        s[i]
    }
}

/// The shared epilogue fold — one expression, used by both the blocked
/// and naive integer kernels so parity is bitwise by construction.
#[inline]
fn fold_i8(
    acc: i32,
    init: f32,
    inv_a: f32,
    zero_a: f32,
    inv_b: f32,
    zero_b: f32,
    sum_a: i32,
    sum_b: i32,
    kf: f32,
) -> f32 {
    let mut y = init;
    y += (inv_a * inv_b) * acc as f32;
    y += (inv_a * zero_b) * sum_a as f32;
    y += (zero_a * inv_b) * sum_b as f32;
    y += (zero_a * zero_b) * kf;
    y
}

/// Pack centered codes row-major into a `rows x kp` i16 panel with
/// per-row code sums. `clear + resize` re-zeroes every element, so a
/// reused scratch vector can never leak stale pad values.
fn pack_rows(dst: &mut Vec<i16>, sums: &mut Vec<i32>, src: &[i8], rows: usize, cols: usize, kp: usize) {
    dst.clear();
    dst.resize(rows * kp, 0);
    sums.clear();
    sums.resize(rows, 0);
    for i in 0..rows {
        let srow = &src[i * cols..(i + 1) * cols];
        let drow = &mut dst[i * kp..i * kp + cols];
        let mut s = 0i32;
        for (d, &v) in drow.iter_mut().zip(srow) {
            *d = i16::from(v);
            s += i32::from(v);
        }
        sums[i] = s;
    }
}

/// Pack the transpose: `src (rows x cols)` becomes a `cols x rp` panel
/// (`rp = padded rows`) with per-column code sums.
fn pack_cols(dst: &mut Vec<i16>, sums: &mut Vec<i32>, src: &[i8], rows: usize, cols: usize, rp: usize) {
    dst.clear();
    dst.resize(cols * rp, 0);
    sums.clear();
    sums.resize(cols, 0);
    for i in 0..rows {
        for (j, &v) in src[i * cols..(i + 1) * cols].iter().enumerate() {
            dst[j * rp + i] = i16::from(v);
            sums[j] += i32::from(v);
        }
    }
}

/// The blocked integer core on packed panels: `acc (m x n) = PA · PBᵀ`
/// in code space (i32, exact), then one fused epilogue pass into f32 C.
/// K is tiled at [`KC`] so the active B panel stays cache-resident; the
/// 4-lane unrolled dot product is the SIMD-friendly inner loop.
#[allow(clippy::too_many_arguments)]
fn gemm_i8_core(
    c: &mut [f32],
    init: Init<'_>,
    pa: &[i16],
    sums_a: &[i32],
    inv_a: &[f32],
    zero_a: &[f32],
    pb: &[i16],
    sums_b: &[i32],
    inv_b: &[f32],
    zero_b: &[f32],
    m: usize,
    n: usize,
    k: usize,
    kp: usize,
    acc: &mut Vec<i32>,
) {
    acc.clear();
    acc.resize(m * n, 0);
    let mut k0 = 0;
    while k0 < kp {
        let k1 = (k0 + KC).min(kp);
        for i in 0..m {
            let ar = &pa[i * kp + k0..i * kp + k1];
            let arow = &mut acc[i * n..(i + 1) * n];
            for (j, av) in arow.iter_mut().enumerate() {
                let br = &pb[j * kp + k0..j * kp + k1];
                let mut s = [0i32; 4];
                for (at, bt) in ar.chunks_exact(4).zip(br.chunks_exact(4)) {
                    s[0] += i32::from(at[0]) * i32::from(bt[0]);
                    s[1] += i32::from(at[1]) * i32::from(bt[1]);
                    s[2] += i32::from(at[2]) * i32::from(bt[2]);
                    s[3] += i32::from(at[3]) * i32::from(bt[3]);
                }
                *av += (s[0] + s[1]) + (s[2] + s[3]);
            }
        }
        k0 = k1;
    }
    let kf = k as f32;
    for i in 0..m {
        let (ia, za) = (sel(inv_a, i), sel(zero_a, i));
        let sa = sums_a[i];
        for j in 0..n {
            let iv = match init {
                Init::Zero => 0.0,
                Init::Bias(bias) => bias[j],
            };
            c[i * n + j] = fold_i8(
                acc[i * n + j],
                iv,
                ia,
                za,
                sel(inv_b, j),
                sel(zero_b, j),
                sa,
                sums_b[j],
                kf,
            );
        }
    }
}

/// Blocked integer `C (m x n) = init + A · Bᵀ` on centered i8 codes:
/// `a` is `m x k` row-major, `bt` is `n x k` row-major (i.e. B supplied
/// transposed — for the hidden-gradient GEMM the `hidden x classes`
/// weight matrix already *is* this layout, so no transpose pass exists
/// on the int path). Scales are per-tensor (len 1) or per-row of the
/// respective operand (len m for A — the PSQ per-sample axis — or len n
/// for Bᵀ); both axes survive the epilogue fold.
#[allow(clippy::too_many_arguments)]
pub fn gemm_i8(
    c: &mut [f32],
    init: Init<'_>,
    a: &[i8],
    inv_a: &[f32],
    zero_a: &[f32],
    bt: &[i8],
    inv_b: &[f32],
    zero_b: &[f32],
    m: usize,
    n: usize,
    k: usize,
    ws: &mut IntGemmScratch,
) {
    assert_eq!(a.len(), m * k, "gemm_i8: A is not m x k");
    assert_eq!(bt.len(), n * k, "gemm_i8: Bt is not n x k");
    assert_eq!(c.len(), m * n, "gemm_i8: C is not m x n");
    assert!(inv_a.len() == 1 || inv_a.len() == m, "gemm_i8: A scale arity");
    assert!(inv_b.len() == 1 || inv_b.len() == n, "gemm_i8: B scale arity");
    assert_eq!(inv_a.len(), zero_a.len());
    assert_eq!(inv_b.len(), zero_b.len());
    debug_assert!(k < (1 << 17), "gemm_i8: i32 accumulator headroom");
    if m == 0 || n == 0 {
        return;
    }
    let kp = padded_k(k);
    pack_rows(&mut ws.pa, &mut ws.sums_a, a, m, k, kp);
    pack_rows(&mut ws.pb, &mut ws.sums_b, bt, n, k, kp);
    let (pa, pb) = (&ws.pa, &ws.pb);
    gemm_i8_core(
        c, init, pa, &ws.sums_a, inv_a, zero_a, pb, &ws.sums_b, inv_b, zero_b, m, n, k, kp,
        &mut ws.acc,
    );
}

/// Blocked integer `C (k x n) = init + Aᵀ · B` on centered i8 codes
/// (`a` is `m x k`, `b` is `m x n`, both row-major) — the weight-
/// gradient contraction over the batch axis. Scales must be per-tensor:
/// a per-row scale here sits on the *contraction* axis and cannot fold
/// into the epilogue (that operand must use the f32 path instead —
/// DESIGN.md §5.1).
#[allow(clippy::too_many_arguments)]
pub fn gemm_i8_at_b(
    c: &mut [f32],
    init: Init<'_>,
    a: &[i8],
    inv_a: &[f32],
    zero_a: &[f32],
    b: &[i8],
    inv_b: &[f32],
    zero_b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    ws: &mut IntGemmScratch,
) {
    assert_eq!(a.len(), m * k, "gemm_i8_at_b: A is not m x k");
    assert_eq!(b.len(), m * n, "gemm_i8_at_b: B is not m x n");
    assert_eq!(c.len(), k * n, "gemm_i8_at_b: C is not k x n");
    assert_eq!(inv_a.len(), 1, "gemm_i8_at_b: A scales must be per-tensor");
    assert_eq!(inv_b.len(), 1, "gemm_i8_at_b: B scales must be per-tensor");
    assert_eq!(zero_a.len(), 1);
    assert_eq!(zero_b.len(), 1);
    debug_assert!(m < (1 << 17), "gemm_i8_at_b: i32 accumulator headroom");
    if k == 0 || n == 0 {
        return;
    }
    let mp = padded_k(m);
    pack_cols(&mut ws.pa, &mut ws.sums_a, a, m, k, mp);
    pack_cols(&mut ws.pb, &mut ws.sums_b, b, m, n, mp);
    let (pa, pb) = (&ws.pa, &ws.pb);
    gemm_i8_core(
        c, init, pa, &ws.sums_a, inv_a, zero_a, pb, &ws.sums_b, inv_b, zero_b, k, n, m, mp,
        &mut ws.acc,
    );
}

/// Integer-path bias-gradient reduction: `out[j] = sum_i deq(codes[i,j])`
/// folded through the per-tensor affine map,
/// `out[j] = inv * colsum_codes[j] + rows * zero`.
pub fn col_sums_i8(out: &mut [f32], codes: &[i8], n: usize, inv: f32, zero: f32) {
    assert_eq!(out.len(), n, "col_sums_i8: out length != n");
    if n == 0 {
        return;
    }
    assert_eq!(codes.len() % n, 0, "col_sums_i8: codes not a multiple of n");
    let rows = codes.len() / n;
    // Exact i32 column sums (strided pass), folded once per column.
    for (j, o) in out.iter_mut().enumerate() {
        let mut s = 0i32;
        let mut idx = j;
        for _ in 0..rows {
            s += i32::from(codes[idx]);
            idx += n;
        }
        *o = inv * s as f32 + rows as f32 * zero;
    }
}

/// Reference kernels: the unblocked triple loops the blocked versions
/// must match bitwise (single accumulator, same per-element add order).
pub mod naive {
    use super::Init;

    pub fn gemm(
        c: &mut [f32],
        init: Init<'_>,
        a: &[f32],
        b: &[f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        assert_eq!(a.len(), m * k);
        assert_eq!(b.len(), k * n);
        assert_eq!(c.len(), m * n);
        for i in 0..m {
            for j in 0..n {
                let mut acc = match init {
                    Init::Zero => 0.0f32,
                    Init::Bias(bias) => bias[j],
                };
                for kk in 0..k {
                    acc += a[i * k + kk] * b[kk * n + j];
                }
                c[i * n + j] = acc;
            }
        }
    }

    pub fn gemm_at_b(
        c: &mut [f32],
        init: Init<'_>,
        a: &[f32],
        b: &[f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        assert_eq!(a.len(), m * k);
        assert_eq!(b.len(), m * n);
        assert_eq!(c.len(), k * n);
        for kk in 0..k {
            for j in 0..n {
                let mut acc = match init {
                    Init::Zero => 0.0f32,
                    Init::Bias(bias) => bias[j],
                };
                for mi in 0..m {
                    acc += a[mi * k + kk] * b[mi * n + j];
                }
                c[kk * n + j] = acc;
            }
        }
    }

    /// Naive integer reference for [`super::gemm_i8`]: triple loop over
    /// the raw (unpacked) codes with a single i32 accumulator, sums
    /// computed on the fly, same [`super::fold_i8`] epilogue — the
    /// blocked kernel must match bitwise (i32 is associative, and the
    /// epilogue expression is literally shared).
    #[allow(clippy::too_many_arguments)]
    pub fn gemm_i8(
        c: &mut [f32],
        init: Init<'_>,
        a: &[i8],
        inv_a: &[f32],
        zero_a: &[f32],
        bt: &[i8],
        inv_b: &[f32],
        zero_b: &[f32],
        m: usize,
        n: usize,
        k: usize,
    ) {
        assert_eq!(a.len(), m * k);
        assert_eq!(bt.len(), n * k);
        assert_eq!(c.len(), m * n);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let sa: i32 = arow.iter().map(|&v| i32::from(v)).sum();
            for j in 0..n {
                let brow = &bt[j * k..(j + 1) * k];
                let sb: i32 = brow.iter().map(|&v| i32::from(v)).sum();
                let mut acc = 0i32;
                for (&av, &bv) in arow.iter().zip(brow) {
                    acc += i32::from(av) * i32::from(bv);
                }
                let iv = match init {
                    Init::Zero => 0.0,
                    Init::Bias(bias) => bias[j],
                };
                c[i * n + j] = super::fold_i8(
                    acc,
                    iv,
                    super::sel(inv_a, i),
                    super::sel(zero_a, i),
                    super::sel(inv_b, j),
                    super::sel(zero_b, j),
                    sa,
                    sb,
                    k as f32,
                );
            }
        }
    }

    /// Naive integer reference for [`super::gemm_i8_at_b`] (per-tensor
    /// scales only, like the blocked kernel).
    #[allow(clippy::too_many_arguments)]
    pub fn gemm_i8_at_b(
        c: &mut [f32],
        init: Init<'_>,
        a: &[i8],
        inv_a: &[f32],
        zero_a: &[f32],
        b: &[i8],
        inv_b: &[f32],
        zero_b: &[f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        assert_eq!(a.len(), m * k);
        assert_eq!(b.len(), m * n);
        assert_eq!(c.len(), k * n);
        assert_eq!(inv_a.len(), 1);
        assert_eq!(inv_b.len(), 1);
        for kk in 0..k {
            let sa: i32 = (0..m).map(|mi| i32::from(a[mi * k + kk])).sum();
            for j in 0..n {
                let sb: i32 = (0..m).map(|mi| i32::from(b[mi * n + j])).sum();
                let mut acc = 0i32;
                for mi in 0..m {
                    acc += i32::from(a[mi * k + kk]) * i32::from(b[mi * n + j]);
                }
                let iv = match init {
                    Init::Zero => 0.0,
                    Init::Bias(bias) => bias[j],
                };
                c[kk * n + j] = super::fold_i8(
                    acc, iv, inv_a[0], zero_a[0], inv_b[0], zero_b[0], sa, sb, m as f32,
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn randv(n: usize, rng: &mut Pcg32) -> Vec<f32> {
        (0..n).map(|_| rng.normal()).collect()
    }

    fn assert_bitwise(a: &[f32], b: &[f32], what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: elem {i}: {x} vs {y}");
        }
    }

    #[test]
    fn gemm_matches_naive_bitwise_across_shapes() {
        let mut rng = Pcg32::new(71, 0);
        // covers: empty axes, M=1, sub-MR remainders, K crossing KC
        for (m, k, n) in [
            (0, 0, 0),
            (1, 1, 1),
            (1, 5, 3),
            (3, 7, 2),
            (4, 0, 8),
            (5, 7, 3),
            (9, 130, 6),
            (16, 300, 11),
        ] {
            let a = randv(m * k, &mut rng);
            let b = randv(k * n, &mut rng);
            let bias = randv(n, &mut rng);
            for init_bias in [false, true] {
                let init = || {
                    if init_bias {
                        Init::Bias(&bias)
                    } else {
                        Init::Zero
                    }
                };
                let mut c_blk = vec![f32::NAN; m * n];
                let mut c_ref = vec![f32::NAN; m * n];
                gemm(&mut c_blk, init(), &a, &b, m, k, n);
                naive::gemm(&mut c_ref, init(), &a, &b, m, k, n);
                assert_bitwise(&c_blk, &c_ref, &format!("gemm {m}x{k}x{n} bias={init_bias}"));
            }
        }
    }

    #[test]
    fn gemm_at_b_matches_naive_bitwise_across_shapes() {
        let mut rng = Pcg32::new(72, 0);
        for (m, k, n) in [
            (0, 3, 2),
            (1, 1, 1),
            (2, 5, 3),
            (4, 4, 4),
            (7, 6, 5),
            (65, 9, 10),
        ] {
            let a = randv(m * k, &mut rng);
            let b = randv(m * n, &mut rng);
            let mut c_blk = vec![f32::NAN; k * n];
            let mut c_ref = vec![f32::NAN; k * n];
            gemm_at_b(&mut c_blk, Init::Zero, &a, &b, m, k, n);
            naive::gemm_at_b(&mut c_ref, Init::Zero, &a, &b, m, k, n);
            assert_bitwise(&c_blk, &c_ref, &format!("gemm_at_b {m}x{k}x{n}"));
        }
    }

    #[test]
    fn k_zero_reduces_to_init() {
        let bias = vec![1.5f32, -2.0, 0.25];
        let mut c = vec![9.0f32; 2 * 3];
        gemm(&mut c, Init::Bias(&bias), &[], &[], 2, 0, 3);
        assert_eq!(c, vec![1.5, -2.0, 0.25, 1.5, -2.0, 0.25]);
        let mut c = vec![9.0f32; 2 * 3];
        gemm(&mut c, Init::Zero, &[], &[], 2, 0, 3);
        assert_eq!(c, vec![0.0; 6]);
    }

    #[test]
    fn zero_times_inf_still_poisons() {
        // the quantizer poison contract: never skip zero multipliers
        let a = [0.0f32];
        let b = [f32::INFINITY];
        let mut c = [0.0f32];
        gemm(&mut c, Init::Zero, &a, &b, 1, 1, 1);
        assert!(c[0].is_nan());
    }

    #[test]
    fn transpose_round_trips() {
        let mut rng = Pcg32::new(73, 0);
        let (r, c) = (5, 7);
        let src = randv(r * c, &mut rng);
        let mut t = vec![0.0f32; r * c];
        let mut back = vec![0.0f32; r * c];
        transpose(&mut t, &src, r, c);
        transpose(&mut back, &t, c, r);
        assert_eq!(src, back);
        assert_eq!(t[3 * r + 2], src[2 * c + 3]);
    }

    #[test]
    fn relu_and_mask_agree_on_subgradient_boundary() {
        let pre = [-1.0f32, -0.0, 0.0, 0.5, 2.0];
        let mut h = [9.0f32; 5];
        relu(&mut h, &pre);
        assert_eq!(h, [0.0, 0.0, 0.0, 0.5, 2.0]);
        let mut g = [1.0f32; 5];
        relu_mask(&mut g, &pre);
        // masked exactly where relu flattened (p <= 0, both zero signs)
        assert_eq!(g, [0.0, 0.0, 0.0, 1.0, 1.0]);
    }

    #[test]
    fn col_sums_matches_manual_reduction() {
        let a = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut out = [0.0f32; 2];
        col_sums(&mut out, &a, 2);
        assert_eq!(out, [1.0 + 3.0 + 5.0, 2.0 + 4.0 + 6.0]);
        let mut empty: [f32; 0] = [];
        col_sums(&mut empty, &[], 0);
    }

    fn randc(n: usize, rng: &mut Pcg32) -> Vec<i8> {
        (0..n)
            .map(|_| ((rng.uniform() * 256.0) as i32 - 128).clamp(-128, 127) as i8)
            .collect()
    }

    #[test]
    fn gemm_i8_matches_naive_bitwise_across_shapes_and_scale_arities() {
        let mut rng = Pcg32::new(81, 0);
        let mut ws = IntGemmScratch::default();
        // covers: empty axes, M=1, K=0, K straddling the KC tile and the
        // 16-wide pad granularity
        for (m, n, k) in [
            (0usize, 0usize, 0usize),
            (1, 1, 1),
            (1, 5, 3),
            (4, 3, 0),
            (5, 7, 16),
            (7, 4, 17),
            (9, 6, 130),
            (16, 11, 300),
        ] {
            let a = randc(m * k, &mut rng);
            let bt = randc(n * k, &mut rng);
            let bias = randv(n, &mut rng);
            for per_row in [false, true] {
                let (inv_a, zero_a) = if per_row {
                    (randv(m, &mut rng), randv(m, &mut rng))
                } else {
                    (randv(1, &mut rng), randv(1, &mut rng))
                };
                let inv_b = randv(1, &mut rng);
                let zero_b = randv(1, &mut rng);
                for init_bias in [false, true] {
                    let init = || {
                        if init_bias {
                            Init::Bias(&bias)
                        } else {
                            Init::Zero
                        }
                    };
                    let mut c_blk = vec![f32::NAN; m * n];
                    let mut c_ref = vec![f32::NAN; m * n];
                    gemm_i8(
                        &mut c_blk, init(), &a, &inv_a, &zero_a, &bt, &inv_b, &zero_b, m, n, k,
                        &mut ws,
                    );
                    naive::gemm_i8(
                        &mut c_ref, init(), &a, &inv_a, &zero_a, &bt, &inv_b, &zero_b, m, n, k,
                    );
                    assert_bitwise(
                        &c_blk,
                        &c_ref,
                        &format!("gemm_i8 {m}x{n}x{k} per_row={per_row} bias={init_bias}"),
                    );
                }
            }
        }
    }

    #[test]
    fn gemm_i8_at_b_matches_naive_bitwise_across_shapes() {
        let mut rng = Pcg32::new(82, 0);
        let mut ws = IntGemmScratch::default();
        for (m, k, n) in [
            (0usize, 3usize, 2usize),
            (1, 1, 1),
            (2, 5, 3),
            (4, 4, 4),
            (17, 6, 5),
            (130, 9, 10),
        ] {
            let a = randc(m * k, &mut rng);
            let b = randc(m * n, &mut rng);
            let inv_a = randv(1, &mut rng);
            let zero_a = randv(1, &mut rng);
            let inv_b = randv(1, &mut rng);
            let zero_b = randv(1, &mut rng);
            let mut c_blk = vec![f32::NAN; k * n];
            let mut c_ref = vec![f32::NAN; k * n];
            gemm_i8_at_b(
                &mut c_blk,
                Init::Zero,
                &a,
                &inv_a,
                &zero_a,
                &b,
                &inv_b,
                &zero_b,
                m,
                k,
                n,
                &mut ws,
            );
            naive::gemm_i8_at_b(
                &mut c_ref, Init::Zero, &a, &inv_a, &zero_a, &b, &inv_b, &zero_b, m, k, n,
            );
            assert_bitwise(&c_blk, &c_ref, &format!("gemm_i8_at_b {m}x{k}x{n}"));
        }
    }

    /// Regression: a reused scratch must not leak a previous (larger)
    /// shape's pad values into a smaller call.
    #[test]
    fn int_scratch_reuse_across_shrinking_shapes_is_clean() {
        let mut rng = Pcg32::new(83, 0);
        let mut ws = IntGemmScratch::default();
        let s1 = randv(1, &mut rng);
        // warm with a big K (pads a wide panel)...
        let a = randc(8 * 300, &mut rng);
        let bt = randc(6 * 300, &mut rng);
        let mut c = vec![0.0f32; 48];
        gemm_i8(&mut c, Init::Zero, &a, &s1, &s1, &bt, &s1, &s1, 8, 6, 300, &mut ws);
        // ...then run a tiny shape whose pad region overlaps stale data
        let a2 = randc(2 * 3, &mut rng);
        let bt2 = randc(2 * 3, &mut rng);
        let mut c_blk = vec![f32::NAN; 4];
        let mut c_ref = vec![f32::NAN; 4];
        gemm_i8(&mut c_blk, Init::Zero, &a2, &s1, &s1, &bt2, &s1, &s1, 2, 2, 3, &mut ws);
        naive::gemm_i8(&mut c_ref, Init::Zero, &a2, &s1, &s1, &bt2, &s1, &s1, 2, 2, 3);
        assert_bitwise(&c_blk, &c_ref, "scratch reuse");
    }

    /// NaN scales (the integer poison channel) spread across exactly the
    /// rows they scope: per-row NaN poisons one output row, per-tensor
    /// NaN poisons everything — even at K = 0.
    #[test]
    fn nan_scales_poison_their_scope() {
        let mut ws = IntGemmScratch::default();
        let a: Vec<i8> = vec![1, 2, 3, 4];
        let bt: Vec<i8> = vec![5, 6, 7, 8];
        let inv_a = vec![0.5, f32::NAN];
        let zero_a = vec![0.0, f32::NAN];
        let s1 = vec![1.0f32];
        let z0 = vec![0.0f32];
        let mut c = vec![0.0f32; 4];
        gemm_i8(&mut c, Init::Zero, &a, &inv_a, &zero_a, &bt, &s1, &z0, 2, 2, 2, &mut ws);
        assert!(c[0].is_finite() && c[1].is_finite());
        assert!(c[2].is_nan() && c[3].is_nan());
        // per-tensor poison at K = 0 still propagates (0 * NaN = NaN)
        let mut c0 = vec![0.0f32; 4];
        let nan1 = vec![f32::NAN];
        gemm_i8(&mut c0, Init::Zero, &[], &nan1, &nan1, &[], &s1, &z0, 2, 2, 0, &mut ws);
        assert!(c0.iter().all(|v| v.is_nan()));
    }

    #[test]
    fn col_sums_i8_folds_affine_map() {
        // codes (3 x 2), inv = 0.5, zero = 1.0:
        // out[j] = 0.5 * colsum + 3 * 1.0
        let codes: Vec<i8> = vec![1, -2, 3, 4, -5, 6];
        let mut out = [0.0f32; 2];
        col_sums_i8(&mut out, &codes, 2, 0.5, 1.0);
        assert_eq!(out, [0.5 * (1 - 5) as f32 + 3.0, 0.5 * (-2 + 4 + 6) as f32 + 3.0]);
        let mut empty: [f32; 0] = [];
        col_sums_i8(&mut empty, &[], 0, 1.0, 0.0);
    }

    #[test]
    fn padded_k_rounds_to_lane_multiple() {
        assert_eq!(padded_k(0), 0);
        assert_eq!(padded_k(1), 16);
        assert_eq!(padded_k(16), 16);
        assert_eq!(padded_k(17), 32);
        assert_eq!(padded_k(130), 144);
    }
}
