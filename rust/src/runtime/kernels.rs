//! Cache-blocked f32 compute kernels for the native executor.
//!
//! Row-major, batched GEMM / GEMM-transpose primitives plus the fused
//! epilogues the MLP interpreter needs (bias-init, relu, relu-mask,
//! column sums). The blocked `gemm` replaces the per-sample triple loops
//! that used to live in `native.rs`; `naive` retains the reference
//! formulation for the golden-parity harness (`tests/kernel_parity.rs`).
//!
//! ## Tiling scheme
//!
//! `gemm` computes `C (m x n) = init + A (m x k) · B (k x n)` as an
//! axpy-style kernel: the K axis is split into [`KC`]-wide tiles (so the
//! active B panel stays cache-resident across the whole row sweep), and
//! rows of C are processed [`MR`] at a time so each B row loaded from
//! cache is reused against `MR` accumulator rows. The inner loop is a
//! column panel (`c[j] += a_ik * b[k][j]` over contiguous `j`) with no
//! horizontal reductions — exactly the shape LLVM's autovectorizer turns
//! into SIMD fma-free lanes.
//!
//! ## Determinism contract (load-bearing)
//!
//! Every output element is produced by a *single* accumulator whose
//! additions happen in ascending-k order (for [`gemm`]) or ascending-m
//! order (for [`gemm_at_b`]), starting from the init value — the same
//! per-element operation sequence as the naive triple loop. Rust f32
//! `a * b + c` lowers to separate IEEE-754 mul and add (never contracted
//! to fma), and vector lanes are element-independent, so the blocked
//! kernels are **bitwise identical** to `naive` regardless of
//! autovectorization. The parity tests assert this; if a future change
//! reassociates an accumulation (e.g. split-K with a reduction tree), it
//! must widen those tests to a tolerance band and update DESIGN.md §5.
//!
//! Zero multipliers are never skipped: `0.0 * inf = NaN` and the
//! quantizers' poison contract depends on NaN propagating through the
//! backward matmuls.

/// K-tile width: `KC * n * 4` bytes of B panel kept hot (for the MLP
/// geometries n is tens of columns, so the panel is well under L1).
pub const KC: usize = 128;

/// Rows of C processed together so one B row load feeds MR accumulator
/// rows held in registers.
pub const MR: usize = 4;

/// How the C buffer is seeded before accumulation.
#[derive(Clone, Copy, Debug)]
pub enum Init<'a> {
    /// `C = 0` before accumulation.
    Zero,
    /// Every row of C starts as this bias vector (len n) — the fused
    /// bias-add epilogue, applied as *initialization* so the add order
    /// matches `bias + sum_k(..)` exactly.
    Bias(&'a [f32]),
}

fn apply_init(c: &mut [f32], init: Init<'_>, n: usize) {
    match init {
        Init::Zero => c.fill(0.0),
        Init::Bias(bias) => {
            assert_eq!(bias.len(), n, "gemm: bias length != n");
            for row in c.chunks_exact_mut(n) {
                row.copy_from_slice(bias);
            }
        }
    }
}

/// One C row accumulating one scaled B row: `c[j] += a * b[j]`.
#[inline]
fn axpy(c: &mut [f32], a: f32, b: &[f32]) {
    for (cv, &bv) in c.iter_mut().zip(b) {
        *cv += a * bv;
    }
}

/// Four C rows accumulating the same B row (the MR = 4 micro-kernel).
/// Each lane touches a distinct output element, so the per-element
/// operation order is identical to four sequential `axpy` calls.
#[inline]
fn axpy4(
    c0: &mut [f32],
    c1: &mut [f32],
    c2: &mut [f32],
    c3: &mut [f32],
    a: [f32; 4],
    b: &[f32],
) {
    for ((((x0, x1), x2), x3), &bv) in c0
        .iter_mut()
        .zip(c1.iter_mut())
        .zip(c2.iter_mut())
        .zip(c3.iter_mut())
        .zip(b)
    {
        *x0 += a[0] * bv;
        *x1 += a[1] * bv;
        *x2 += a[2] * bv;
        *x3 += a[3] * bv;
    }
}

/// One C row accumulating four (scalar, B row) pairs in ascending sample
/// order — the [`gemm_at_b`] micro-kernel. The adds chain through one
/// accumulator per element, preserving the m-ascending order.
#[inline]
fn axpy_m4(c: &mut [f32], a: [f32; 4], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32]) {
    for ((((cv, &v0), &v1), &v2), &v3) in c.iter_mut().zip(b0).zip(b1).zip(b2).zip(b3) {
        let mut acc = *cv;
        acc += a[0] * v0;
        acc += a[1] * v1;
        acc += a[2] * v2;
        acc += a[3] * v3;
        *cv = acc;
    }
}

/// Blocked `C (m x n) = init + A (m x k) · B (k x n)`, all row-major.
pub fn gemm(c: &mut [f32], init: Init<'_>, a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k, "gemm: A is not m x k");
    assert_eq!(b.len(), k * n, "gemm: B is not k x n");
    assert_eq!(c.len(), m * n, "gemm: C is not m x n");
    if m == 0 || n == 0 {
        return;
    }
    apply_init(c, init, n);
    let mut k0 = 0;
    while k0 < k {
        let k1 = (k0 + KC).min(k);
        let mut i = 0;
        while i + MR <= m {
            let rows = &mut c[i * n..(i + MR) * n];
            let (c0, rest) = rows.split_at_mut(n);
            let (c1, rest) = rest.split_at_mut(n);
            let (c2, c3) = rest.split_at_mut(n);
            for kk in k0..k1 {
                let scal = [
                    a[i * k + kk],
                    a[(i + 1) * k + kk],
                    a[(i + 2) * k + kk],
                    a[(i + 3) * k + kk],
                ];
                axpy4(c0, c1, c2, c3, scal, &b[kk * n..(kk + 1) * n]);
            }
            i += MR;
        }
        while i < m {
            let crow = &mut c[i * n..(i + 1) * n];
            for kk in k0..k1 {
                axpy(crow, a[i * k + kk], &b[kk * n..(kk + 1) * n]);
            }
            i += 1;
        }
        k0 = k1;
    }
}

/// Blocked `C (k x n) = init + Aᵀ · B` for row-major `A (m x k)` and
/// `B (m x n)` — the weight-gradient contraction over the batch axis.
/// Samples are consumed in ascending order (four at a time), so each
/// output element accumulates in the same order as the naive loop.
pub fn gemm_at_b(
    c: &mut [f32],
    init: Init<'_>,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
) {
    assert_eq!(a.len(), m * k, "gemm_at_b: A is not m x k");
    assert_eq!(b.len(), m * n, "gemm_at_b: B is not m x n");
    assert_eq!(c.len(), k * n, "gemm_at_b: C is not k x n");
    if k == 0 || n == 0 {
        return;
    }
    apply_init(c, init, n);
    let mut mi = 0;
    while mi + 4 <= m {
        let a0 = &a[mi * k..(mi + 1) * k];
        let a1 = &a[(mi + 1) * k..(mi + 2) * k];
        let a2 = &a[(mi + 2) * k..(mi + 3) * k];
        let a3 = &a[(mi + 3) * k..(mi + 4) * k];
        let b0 = &b[mi * n..(mi + 1) * n];
        let b1 = &b[(mi + 1) * n..(mi + 2) * n];
        let b2 = &b[(mi + 2) * n..(mi + 3) * n];
        let b3 = &b[(mi + 3) * n..(mi + 4) * n];
        for (kk, crow) in c.chunks_exact_mut(n).enumerate() {
            axpy_m4(crow, [a0[kk], a1[kk], a2[kk], a3[kk]], b0, b1, b2, b3);
        }
        mi += 4;
    }
    while mi < m {
        let ai = &a[mi * k..(mi + 1) * k];
        let bi = &b[mi * n..(mi + 1) * n];
        for (kk, crow) in c.chunks_exact_mut(n).enumerate() {
            axpy(crow, ai[kk], bi);
        }
        mi += 1;
    }
}

/// `dst (cols x rows) = srcᵀ` for row-major `src (rows x cols)`.
pub fn transpose(dst: &mut [f32], src: &[f32], rows: usize, cols: usize) {
    assert_eq!(src.len(), rows * cols, "transpose: src shape");
    assert_eq!(dst.len(), rows * cols, "transpose: dst shape");
    if rows == 0 || cols == 0 {
        return;
    }
    for (i, row) in src.chunks_exact(cols).enumerate() {
        for (j, &v) in row.iter().enumerate() {
            dst[j * rows + i] = v;
        }
    }
}

/// Elementwise `dst = max(src, 0)`.
pub fn relu(dst: &mut [f32], src: &[f32]) {
    assert_eq!(dst.len(), src.len(), "relu: shape mismatch");
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = s.max(0.0);
    }
}

/// Zero `g` wherever the pre-activation was non-positive (the backward
/// relu mask; `<= 0.0` matches the forward `max(0.0)` subgradient).
pub fn relu_mask(g: &mut [f32], pre: &[f32]) {
    assert_eq!(g.len(), pre.len(), "relu_mask: shape mismatch");
    for (v, &p) in g.iter_mut().zip(pre) {
        if p <= 0.0 {
            *v = 0.0;
        }
    }
}

/// `out (n) = column sums of a (rows x n)`, rows consumed in ascending
/// order — the bias-gradient reduction.
pub fn col_sums(out: &mut [f32], a: &[f32], n: usize) {
    assert_eq!(out.len(), n, "col_sums: out length != n");
    out.fill(0.0);
    if n == 0 {
        return;
    }
    assert_eq!(a.len() % n, 0, "col_sums: A not a multiple of n");
    for row in a.chunks_exact(n) {
        for (o, &v) in out.iter_mut().zip(row) {
            *o += v;
        }
    }
}

/// Reference kernels: the unblocked triple loops the blocked versions
/// must match bitwise (single accumulator, same per-element add order).
pub mod naive {
    use super::Init;

    pub fn gemm(
        c: &mut [f32],
        init: Init<'_>,
        a: &[f32],
        b: &[f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        assert_eq!(a.len(), m * k);
        assert_eq!(b.len(), k * n);
        assert_eq!(c.len(), m * n);
        for i in 0..m {
            for j in 0..n {
                let mut acc = match init {
                    Init::Zero => 0.0f32,
                    Init::Bias(bias) => bias[j],
                };
                for kk in 0..k {
                    acc += a[i * k + kk] * b[kk * n + j];
                }
                c[i * n + j] = acc;
            }
        }
    }

    pub fn gemm_at_b(
        c: &mut [f32],
        init: Init<'_>,
        a: &[f32],
        b: &[f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        assert_eq!(a.len(), m * k);
        assert_eq!(b.len(), m * n);
        assert_eq!(c.len(), k * n);
        for kk in 0..k {
            for j in 0..n {
                let mut acc = match init {
                    Init::Zero => 0.0f32,
                    Init::Bias(bias) => bias[j],
                };
                for mi in 0..m {
                    acc += a[mi * k + kk] * b[mi * n + j];
                }
                c[kk * n + j] = acc;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn randv(n: usize, rng: &mut Pcg32) -> Vec<f32> {
        (0..n).map(|_| rng.normal()).collect()
    }

    fn assert_bitwise(a: &[f32], b: &[f32], what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: elem {i}: {x} vs {y}");
        }
    }

    #[test]
    fn gemm_matches_naive_bitwise_across_shapes() {
        let mut rng = Pcg32::new(71, 0);
        // covers: empty axes, M=1, sub-MR remainders, K crossing KC
        for (m, k, n) in [
            (0, 0, 0),
            (1, 1, 1),
            (1, 5, 3),
            (3, 7, 2),
            (4, 0, 8),
            (5, 7, 3),
            (9, 130, 6),
            (16, 300, 11),
        ] {
            let a = randv(m * k, &mut rng);
            let b = randv(k * n, &mut rng);
            let bias = randv(n, &mut rng);
            for init_bias in [false, true] {
                let init = || {
                    if init_bias {
                        Init::Bias(&bias)
                    } else {
                        Init::Zero
                    }
                };
                let mut c_blk = vec![f32::NAN; m * n];
                let mut c_ref = vec![f32::NAN; m * n];
                gemm(&mut c_blk, init(), &a, &b, m, k, n);
                naive::gemm(&mut c_ref, init(), &a, &b, m, k, n);
                assert_bitwise(&c_blk, &c_ref, &format!("gemm {m}x{k}x{n} bias={init_bias}"));
            }
        }
    }

    #[test]
    fn gemm_at_b_matches_naive_bitwise_across_shapes() {
        let mut rng = Pcg32::new(72, 0);
        for (m, k, n) in [
            (0, 3, 2),
            (1, 1, 1),
            (2, 5, 3),
            (4, 4, 4),
            (7, 6, 5),
            (65, 9, 10),
        ] {
            let a = randv(m * k, &mut rng);
            let b = randv(m * n, &mut rng);
            let mut c_blk = vec![f32::NAN; k * n];
            let mut c_ref = vec![f32::NAN; k * n];
            gemm_at_b(&mut c_blk, Init::Zero, &a, &b, m, k, n);
            naive::gemm_at_b(&mut c_ref, Init::Zero, &a, &b, m, k, n);
            assert_bitwise(&c_blk, &c_ref, &format!("gemm_at_b {m}x{k}x{n}"));
        }
    }

    #[test]
    fn k_zero_reduces_to_init() {
        let bias = vec![1.5f32, -2.0, 0.25];
        let mut c = vec![9.0f32; 2 * 3];
        gemm(&mut c, Init::Bias(&bias), &[], &[], 2, 0, 3);
        assert_eq!(c, vec![1.5, -2.0, 0.25, 1.5, -2.0, 0.25]);
        let mut c = vec![9.0f32; 2 * 3];
        gemm(&mut c, Init::Zero, &[], &[], 2, 0, 3);
        assert_eq!(c, vec![0.0; 6]);
    }

    #[test]
    fn zero_times_inf_still_poisons() {
        // the quantizer poison contract: never skip zero multipliers
        let a = [0.0f32];
        let b = [f32::INFINITY];
        let mut c = [0.0f32];
        gemm(&mut c, Init::Zero, &a, &b, 1, 1, 1);
        assert!(c[0].is_nan());
    }

    #[test]
    fn transpose_round_trips() {
        let mut rng = Pcg32::new(73, 0);
        let (r, c) = (5, 7);
        let src = randv(r * c, &mut rng);
        let mut t = vec![0.0f32; r * c];
        let mut back = vec![0.0f32; r * c];
        transpose(&mut t, &src, r, c);
        transpose(&mut back, &t, c, r);
        assert_eq!(src, back);
        assert_eq!(t[3 * r + 2], src[2 * c + 3]);
    }

    #[test]
    fn relu_and_mask_agree_on_subgradient_boundary() {
        let pre = [-1.0f32, -0.0, 0.0, 0.5, 2.0];
        let mut h = [9.0f32; 5];
        relu(&mut h, &pre);
        assert_eq!(h, [0.0, 0.0, 0.0, 0.5, 2.0]);
        let mut g = [1.0f32; 5];
        relu_mask(&mut g, &pre);
        // masked exactly where relu flattened (p <= 0, both zero signs)
        assert_eq!(g, [0.0, 0.0, 0.0, 1.0, 1.0]);
    }

    #[test]
    fn col_sums_matches_manual_reduction() {
        let a = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut out = [0.0f32; 2];
        col_sums(&mut out, &a, 2);
        assert_eq!(out, [1.0 + 3.0 + 5.0, 2.0 + 4.0 + 6.0]);
        let mut empty: [f32; 0] = [];
        col_sums(&mut empty, &[], 0);
    }
}
