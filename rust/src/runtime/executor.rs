//! Executable wrapper: HLO text -> PJRT compile -> validated execute.

use anyhow::{anyhow, bail, Context, Result};

use super::artifact::ArtifactMeta;
use super::Runtime;

/// Host-side tensor crossing the ABI.
#[derive(Clone, Debug)]
pub enum HostTensor {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl HostTensor {
    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32(v) => v.len(),
            HostTensor::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(v) => Ok(v),
            HostTensor::I32(_) => bail!("expected f32 tensor, got i32"),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            HostTensor::F32(v) => Ok(v),
            HostTensor::I32(_) => bail!("expected f32 tensor, got i32"),
        }
    }

    fn to_literal(&self, shape: &[usize]) -> Result<xla::Literal> {
        if shape.is_empty() {
            // rank-0 scalar
            return Ok(match self {
                HostTensor::F32(v) => xla::Literal::scalar(v[0]),
                HostTensor::I32(v) => xla::Literal::scalar(v[0]),
            });
        }
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32(v) => xla::Literal::vec1(v),
            HostTensor::I32(v) => xla::Literal::vec1(v),
        };
        if shape.len() == 1 && lit.element_count() == shape[0] {
            return Ok(lit);
        }
        Ok(lit.reshape(&dims)?)
    }

    fn from_literal(lit: &xla::Literal) -> Result<Self> {
        use xla::ElementType;
        match lit.ty()? {
            ElementType::F32 => Ok(HostTensor::F32(lit.to_vec::<f32>()?)),
            ElementType::S32 => Ok(HostTensor::I32(lit.to_vec::<i32>()?)),
            other => bail!("unsupported output element type {other:?}"),
        }
    }
}

/// Outputs of one step execution, in ABI order.
pub type StepOutputs = Vec<HostTensor>;

/// One compiled artifact, ready to execute.
pub struct Executor {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl Executor {
    /// Load the artifact's HLO text and compile it on the PJRT client.
    pub fn load(rt: &Runtime, meta: &ArtifactMeta) -> Result<Self> {
        let proto = xla::HloModuleProto::from_text_file(&meta.hlo_path)
            .with_context(|| format!("loading {}", meta.hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = rt
            .client()
            .compile(&comp)
            .with_context(|| format!("compiling {}", meta.key()))?;
        Ok(Self {
            meta: meta.clone(),
            exe,
        })
    }

    /// Execute with validated inputs; returns decomposed tuple outputs.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<StepOutputs> {
        if inputs.len() != self.meta.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.meta.key(),
                self.meta.inputs.len(),
                inputs.len()
            );
        }
        let mut lits = Vec::with_capacity(inputs.len());
        for (i, (t, spec)) in inputs.iter().zip(&self.meta.inputs).enumerate() {
            if t.len() != spec.numel() {
                bail!(
                    "{} input {i}: expected {} elements {:?}, got {}",
                    self.meta.key(),
                    spec.numel(),
                    spec.shape,
                    t.len()
                );
            }
            let want_i32 = spec.dtype.starts_with("int");
            let is_i32 = matches!(t, HostTensor::I32(_));
            if want_i32 != is_i32 {
                bail!(
                    "{} input {i}: dtype mismatch (artifact wants {}, got {})",
                    self.meta.key(),
                    spec.dtype,
                    if is_i32 { "i32" } else { "f32" }
                );
            }
            lits.push(t.to_literal(&spec.shape)?);
        }
        let result = self.exe.execute::<xla::Literal>(&lits)?;
        let tuple = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| anyhow!("empty execution result"))?
            .to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        if parts.len() != self.meta.outputs.len() {
            bail!(
                "{}: expected {} outputs, got {}",
                self.meta.key(),
                self.meta.outputs.len(),
                parts.len()
            );
        }
        parts.iter().map(HostTensor::from_literal).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_accessors() {
        let f = HostTensor::F32(vec![1.0, 2.0]);
        assert_eq!(f.len(), 2);
        assert!(f.as_f32().is_ok());
        let i = HostTensor::I32(vec![1, 2, 3]);
        assert_eq!(i.len(), 3);
        assert!(i.as_f32().is_err());
    }

    #[test]
    fn literal_roundtrip_f32() {
        let t = HostTensor::F32(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let lit = t.to_literal(&[2, 3]).unwrap();
        assert_eq!(lit.element_count(), 6);
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(back.as_f32().unwrap(), &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn literal_scalar_shape() {
        let t = HostTensor::F32(vec![7.5]);
        let lit = t.to_literal(&[]).unwrap();
        assert_eq!(lit.element_count(), 1);
    }
}
