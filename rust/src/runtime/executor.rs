//! Backend-agnostic executor: the [`ExecutorBackend`] trait plus the
//! validating [`Executor`] facade every coordinator-layer caller holds.
//!
//! The facade owns all ABI checking (arity, per-tensor numel, dtype,
//! output arity/numel) against the artifact's JSON metadata, so a
//! backend only ever sees inputs that already match the declared
//! signature and callers get identical error surfaces regardless of
//! which backend runs the step.

use anyhow::{bail, Result};

use super::artifact::{ArtifactMeta, StepKind};
use crate::obs;

/// Host-side tensor crossing the ABI.
#[derive(Clone, Debug)]
pub enum HostTensor {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl HostTensor {
    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32(v) => v.len(),
            HostTensor::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(v) => Ok(v),
            HostTensor::I32(_) => bail!("expected f32 tensor, got i32"),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            HostTensor::F32(v) => Ok(v),
            HostTensor::I32(_) => bail!("expected f32 tensor, got i32"),
        }
    }
}

/// Outputs of one step execution, in ABI order.
pub type StepOutputs = Vec<HostTensor>;

/// One way of executing a step artifact. Implementations receive inputs
/// the facade has already validated against `meta.inputs` and must
/// return outputs in `meta.outputs` order (the facade re-checks arity
/// and numel on the way out).
///
/// `Send + Sync` so one `Executor` can be dispatched concurrently from
/// the data-parallel worker pool: `execute` takes `&self` and carries
/// all per-call state in its arguments.
pub trait ExecutorBackend: Send + Sync {
    /// Short backend identifier for logs ("native", "pjrt", ...).
    fn name(&self) -> &'static str;

    /// Run one step.
    fn execute(&self, meta: &ArtifactMeta, inputs: &[HostTensor]) -> Result<StepOutputs>;
}

/// One loaded artifact, ready to execute on some backend.
pub struct Executor {
    pub meta: ArtifactMeta,
    backend: Box<dyn ExecutorBackend>,
    dispatches: obs::Counter,
    latency: obs::HistogramMetric,
}

impl Executor {
    pub fn new(meta: ArtifactMeta, backend: Box<dyn ExecutorBackend>) -> Self {
        let labels = [("backend", backend.name()), ("step", meta.step.name())];
        let m = obs::metrics();
        let dispatches = m.counter(
            &obs::registry::labeled("executor_dispatch_total", &labels),
            "step executions dispatched to a backend",
        );
        let latency = m.histogram(
            &obs::registry::labeled("executor_dispatch_seconds", &labels),
            "wall time of one backend execute",
            &obs::registry::TIME_BUCKETS,
        );
        Self {
            meta,
            backend,
            dispatches,
            latency,
        }
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Execute with validated inputs; returns decomposed tuple outputs.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<StepOutputs> {
        if inputs.len() != self.meta.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.meta.key(),
                self.meta.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, spec)) in inputs.iter().zip(&self.meta.inputs).enumerate() {
            if t.len() != spec.numel() {
                bail!(
                    "{} input {i}: expected {} elements {:?}, got {}",
                    self.meta.key(),
                    spec.numel(),
                    spec.shape,
                    t.len()
                );
            }
            let want_i32 = spec.dtype.starts_with("int");
            let is_i32 = matches!(t, HostTensor::I32(_));
            if want_i32 != is_i32 {
                bail!(
                    "{} input {i}: dtype mismatch (artifact wants {}, got {})",
                    self.meta.key(),
                    spec.dtype,
                    if is_i32 { "i32" } else { "f32" }
                );
            }
        }
        let outputs = if obs::enabled() {
            let _sp = obs::span(match self.meta.step {
                StepKind::Train => "exec/train",
                StepKind::Probe => "exec/probe",
                StepKind::Eval => "exec/eval",
                StepKind::ActGrad => "exec/actgrad",
            });
            let t0 = std::time::Instant::now();
            let out = self.backend.execute(&self.meta, inputs)?;
            self.latency.observe(t0.elapsed().as_secs_f64());
            self.dispatches.inc();
            out
        } else {
            self.backend.execute(&self.meta, inputs)?
        };
        if outputs.len() != self.meta.outputs.len() {
            bail!(
                "{}: expected {} outputs, got {}",
                self.meta.key(),
                self.meta.outputs.len(),
                outputs.len()
            );
        }
        for (i, (t, spec)) in outputs.iter().zip(&self.meta.outputs).enumerate() {
            if t.len() != spec.numel() {
                bail!(
                    "{} output {i}: expected {} elements {:?}, got {}",
                    self.meta.key(),
                    spec.numel(),
                    spec.shape,
                    t.len()
                );
            }
        }
        Ok(outputs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::{StepKind, TensorSpec};
    use std::path::PathBuf;

    #[test]
    fn host_tensor_accessors() {
        let f = HostTensor::F32(vec![1.0, 2.0]);
        assert_eq!(f.len(), 2);
        assert!(f.as_f32().is_ok());
        let i = HostTensor::I32(vec![1, 2, 3]);
        assert_eq!(i.len(), 3);
        assert!(i.as_f32().is_err());
    }

    /// Backend that echoes its f32 inputs back, for facade validation tests.
    struct Echo;

    impl ExecutorBackend for Echo {
        fn name(&self) -> &'static str {
            "echo"
        }
        fn execute(&self, _meta: &ArtifactMeta, inputs: &[HostTensor]) -> Result<StepOutputs> {
            Ok(inputs.to_vec())
        }
    }

    fn spec(shape: &[usize], dtype: &str) -> TensorSpec {
        TensorSpec {
            shape: shape.to_vec(),
            dtype: dtype.to_string(),
        }
    }

    fn echo_exec() -> Executor {
        let meta = ArtifactMeta {
            model: "m".into(),
            variant: "v".into(),
            step: StepKind::Train,
            n_params: 2,
            batch: 1,
            input_shape: vec![2],
            input_dtype: "float32".into(),
            inputs: vec![spec(&[2], "float32"), spec(&[], "int32")],
            outputs: vec![spec(&[2], "float32"), spec(&[], "int32")],
            probe_shape: vec![2],
            momentum: 0.9,
            hlo_path: PathBuf::from("echo.hlo.txt"),
        };
        Executor::new(meta, Box::new(Echo))
    }

    #[test]
    fn facade_validates_and_dispatches() {
        let exec = echo_exec();
        assert_eq!(exec.backend_name(), "echo");
        let out = exec
            .run(&[HostTensor::F32(vec![1.0, 2.0]), HostTensor::I32(vec![3])])
            .unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].as_f32().unwrap(), &[1.0, 2.0]);
    }

    #[test]
    fn facade_rejects_bad_arity_numel_dtype() {
        let exec = echo_exec();
        // arity
        assert!(exec.run(&[HostTensor::F32(vec![1.0, 2.0])]).is_err());
        // numel
        assert!(exec
            .run(&[HostTensor::F32(vec![1.0]), HostTensor::I32(vec![3])])
            .is_err());
        // dtype
        assert!(exec
            .run(&[
                HostTensor::F32(vec![1.0, 2.0]),
                HostTensor::F32(vec![3.0])
            ])
            .is_err());
    }
}
