//! Artifact metadata + registry.
//!
//! `python/compile/aot.py` writes, per artifact, an `.hlo.txt` module and
//! a `.json` sidecar describing the ABI (input/output shapes + dtypes,
//! parameter count, model config). The [`Registry`] discovers artifacts,
//! validates sidecars, and hands compiled executables to the coordinator,
//! caching one executable per (model, variant, step).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// The four step-function kinds emitted by aot.py (DESIGN.md §2 ABI).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum StepKind {
    Train,
    Probe,
    Eval,
    ActGrad,
}

impl StepKind {
    pub fn name(self) -> &'static str {
        match self {
            StepKind::Train => "train",
            StepKind::Probe => "probe",
            StepKind::Eval => "eval",
            StepKind::ActGrad => "actgrad",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        match s {
            "train" => Some(StepKind::Train),
            "probe" => Some(StepKind::Probe),
            "eval" => Some(StepKind::Eval),
            "actgrad" => Some(StepKind::ActGrad),
            _ => None,
        }
    }
}

/// Shape + dtype of one ABI tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String, // "float32" | "int32"
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<Self> {
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing shape"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = j
            .get("dtype")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("missing dtype"))?
            .to_string();
        Ok(Self { shape, dtype })
    }
}

/// Parsed sidecar for one artifact.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub model: String,
    pub variant: String,
    pub step: StepKind,
    pub n_params: usize,
    pub batch: usize,
    pub input_shape: Vec<usize>,
    pub input_dtype: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub probe_shape: Vec<usize>,
    pub momentum: f64,
    pub hlo_path: PathBuf,
}

impl ArtifactMeta {
    pub fn parse(json_path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(json_path)
            .with_context(|| format!("reading {}", json_path.display()))?;
        let j = Json::parse(&text)
            .with_context(|| format!("parsing {}", json_path.display()))?;
        let get_str = |k: &str| -> Result<String> {
            Ok(j.get(k)
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("missing {k}"))?
                .to_string())
        };
        let get_num = |k: &str| -> Result<usize> {
            j.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("missing {k}"))
        };
        let specs = |k: &str| -> Result<Vec<TensorSpec>> {
            j.get(k)
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("missing {k}"))?
                .iter()
                .map(TensorSpec::from_json)
                .collect()
        };
        let dims = |k: &str| -> Result<Vec<usize>> {
            j.get(k)
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("missing {k}"))?
                .iter()
                .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad dim")))
                .collect()
        };
        let step_name = get_str("step")?;
        let step = StepKind::from_name(&step_name)
            .ok_or_else(|| anyhow!("unknown step kind {step_name}"))?;
        let hlo_path = json_path.with_extension("").with_extension("hlo.txt");
        Ok(Self {
            model: get_str("model")?,
            variant: get_str("variant")?,
            step,
            n_params: get_num("n_params")?,
            batch: get_num("batch")?,
            input_shape: dims("input_shape")?,
            input_dtype: get_str("input_dtype")?,
            inputs: specs("inputs")?,
            outputs: specs("outputs")?,
            probe_shape: dims("probe_shape")?,
            momentum: j
                .get("momentum")
                .and_then(Json::as_f64)
                .unwrap_or(0.9),
            hlo_path,
        })
    }

    pub fn key(&self) -> String {
        format!("{}_{}_{}", self.model, self.variant, self.step.name())
    }
}

/// Discovers artifacts in a directory and caches compiled executables.
pub struct Registry {
    pub dir: PathBuf,
    metas: HashMap<String, ArtifactMeta>,
    inits: HashMap<String, PathBuf>,
}

impl Registry {
    pub fn open(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        if !dir.is_dir() {
            bail!(
                "artifact dir {} missing — run `make artifacts` first",
                dir.display()
            );
        }
        let mut metas = HashMap::new();
        let mut inits = HashMap::new();
        for entry in std::fs::read_dir(&dir)? {
            let path = entry?.path();
            let name = path
                .file_name()
                .and_then(|s| s.to_str())
                .unwrap_or_default()
                .to_string();
            if name.ends_with(".json") && name != "manifest.json" {
                let meta = ArtifactMeta::parse(&path)
                    .with_context(|| format!("bad sidecar {name}"))?;
                metas.insert(meta.key(), meta);
            } else if let Some(model) = name.strip_suffix("_init.bin") {
                inits.insert(model.to_string(), path);
            }
        }
        Ok(Self { dir, metas, inits })
    }

    pub fn meta(&self, model: &str, variant: &str, step: StepKind) -> Result<&ArtifactMeta> {
        let key = format!("{model}_{variant}_{}", step.name());
        self.metas.get(&key).ok_or_else(|| {
            anyhow!(
                "artifact {key} not found in {} (have: {:?})",
                self.dir.display(),
                {
                    let mut keys: Vec<_> = self.metas.keys().collect();
                    keys.sort();
                    keys
                }
            )
        })
    }

    pub fn keys(&self) -> Vec<&str> {
        self.metas.keys().map(String::as_str).collect()
    }

    /// Load the f32-LE initial parameter vector written by aot.py.
    pub fn init_params(&self, model: &str) -> Result<Vec<f32>> {
        let path = self
            .inits
            .get(model)
            .ok_or_else(|| anyhow!("no init params for model {model}"))?;
        let bytes = std::fs::read(path)?;
        if bytes.len() % 4 != 0 {
            bail!("init file {} not a multiple of 4 bytes", path.display());
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }

    pub fn models(&self) -> Vec<&str> {
        self.inits.keys().map(String::as_str).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_kind_roundtrip() {
        for k in [
            StepKind::Train,
            StepKind::Probe,
            StepKind::Eval,
            StepKind::ActGrad,
        ] {
            assert_eq!(StepKind::from_name(k.name()), Some(k));
        }
        assert_eq!(StepKind::from_name("bogus"), None);
    }

    #[test]
    fn tensor_spec_numel() {
        let t = TensorSpec {
            shape: vec![4, 8, 2],
            dtype: "float32".into(),
        };
        assert_eq!(t.numel(), 64);
        let scalar = TensorSpec {
            shape: vec![],
            dtype: "float32".into(),
        };
        assert_eq!(scalar.numel(), 1);
    }

    #[test]
    fn parse_sidecar_from_tempfile() {
        let dir = std::env::temp_dir().join(format!("sq_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let sidecar = dir.join("mlp_ptq_train.json");
        std::fs::write(
            &sidecar,
            r#"{"model":"mlp","variant":"ptq","step":"train","n_params":10,
               "batch":4,"input_shape":[4,8],"input_dtype":"f32",
               "inputs":[{"shape":[10],"dtype":"float32"}],
               "outputs":[{"shape":[10],"dtype":"float32"}],
               "probe_shape":[4,16],"momentum":0.9}"#,
        )
        .unwrap();
        std::fs::write(dir.join("mlp_init.bin"), 1f32.to_le_bytes()).unwrap();
        let reg = Registry::open(&dir).unwrap();
        let meta = reg.meta("mlp", "ptq", StepKind::Train).unwrap();
        assert_eq!(meta.n_params, 10);
        assert_eq!(meta.hlo_path.file_name().unwrap(), "mlp_ptq_train.hlo.txt");
        assert_eq!(reg.init_params("mlp").unwrap(), vec![1.0]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_dir_errors() {
        assert!(Registry::open("/nonexistent/path/xyz").is_err());
    }
}
