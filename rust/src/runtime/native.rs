//! Native interpreter backend: a pure-Rust implementation of the probe
//! artifacts' two-layer MLP forward/backward, so the coordinator,
//! experiments, and data-parallel stack run end-to-end on machines
//! without an XLA/PJRT toolchain.
//!
//! The interpreter reuses the native quantizer stack ([`crate::quant`]):
//! FQT variants quantize the backward signal matrices (the logit
//! gradient and the hidden-layer gradient, one sample per row — the
//! paper's per-sample axis) with stochastic rounding, so Theorem-1
//! unbiasedness and the §4 variance ordering hold through this backend
//! exactly as through the lowered HLO.
//!
//! Two kernel paths implement the same math (see DESIGN.md §5):
//!
//!  * **blocked** (default) — whole-batch cache-blocked GEMMs from
//!    [`super::kernels`] plus a per-thread [`Workspace`] arena, so the
//!    hot loop does no heap allocation after warm-up and the quantizers
//!    run their fused single-pass `apply_into` entry points;
//!  * **reference** — the original per-sample interpreter, retained
//!    verbatim as the golden reference. The two paths are bitwise
//!    identical (kernel accumulation order is preserved; enforced by
//!    `tests/kernel_parity.rs`), and their latency ratio is the
//!    `native_step_speedup` bench headline.
//!
//! Artifact files are the same `.json` sidecars the Python AOT pipeline
//! writes (plus placeholder `.hlo.txt` files, since there is no HLO to
//! lower offline); [`write_artifacts`] generates a complete `mlp` set so
//! a clean checkout can produce runnable artifacts with
//! `statquant gen-artifacts`.
//!
//! Parameter layout (flat f32 vector, matching the sidecar `n_params`):
//! `W1 (in_dim x hidden) | b1 (hidden) | W2 (hidden x classes) | b2 (classes)`

use std::cell::RefCell;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use super::artifact::{ArtifactMeta, StepKind};
use super::executor::{ExecutorBackend, HostTensor, StepOutputs};
use super::kernels::{self, Init};
use crate::obs::{Counter, Gauge};
use crate::quant::{ptq, CodeMat, CodeScales, FusedScratch, GradQuantizer, Mat};
use crate::util::json::{obj, Json};
use crate::util::rng::Pcg32;

/// Model geometry for artifact generation.
#[derive(Clone, Copy, Debug)]
pub struct MlpSpec {
    pub in_dim: usize,
    pub hidden: usize,
    pub classes: usize,
    pub batch: usize,
    /// Seed for the He-initialised parameter vector.
    pub seed: u64,
}

impl Default for MlpSpec {
    fn default() -> Self {
        Self {
            in_dim: 64,
            hidden: 32,
            classes: 10,
            batch: 64,
            seed: 0x5EED,
        }
    }
}

impl MlpSpec {
    pub fn n_params(&self) -> usize {
        self.in_dim * self.hidden
            + self.hidden
            + self.hidden * self.classes
            + self.classes
    }
}

/// Variants emitted by [`write_artifacts`] (train + probe each).
pub const VARIANTS: [&str; 5] = ["exact", "qat", "ptq", "psq", "bhq"];

/// Geometry recovered from an artifact's ABI metadata — the sidecar
/// schema carries no explicit layer sizes, but for the two-layer MLP
/// they are all determined by `input_shape`, `probe_shape`, `n_params`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct MlpDims {
    batch: usize,
    in_dim: usize,
    hidden: usize,
    classes: usize,
}

impl MlpDims {
    fn infer(meta: &ArtifactMeta) -> Result<Self> {
        if meta.model != "mlp" {
            bail!(
                "native backend only interprets the `mlp` model (artifact is `{}`); \
                 build with `--features pjrt` and real XLA bindings for other models",
                meta.model
            );
        }
        if meta.input_shape.len() < 2 {
            bail!("mlp input_shape {:?} must be [batch, dims...]", meta.input_shape);
        }
        let batch = meta.input_shape[0];
        let in_dim: usize = meta.input_shape[1..].iter().product();
        if meta.probe_shape.len() != 2 || meta.probe_shape[0] != batch {
            bail!(
                "probe_shape {:?} must be [batch={batch}, hidden]",
                meta.probe_shape
            );
        }
        let hidden = meta.probe_shape[1];
        if batch == 0 || in_dim == 0 || hidden == 0 {
            bail!("degenerate mlp dims: batch {batch}, in_dim {in_dim}, hidden {hidden}");
        }
        let rem = meta
            .n_params
            .checked_sub(hidden * (in_dim + 1))
            .ok_or_else(|| anyhow!("n_params {} too small for layer 1", meta.n_params))?;
        if rem % (hidden + 1) != 0 {
            bail!(
                "n_params {} inconsistent with in_dim {in_dim}, hidden {hidden}",
                meta.n_params
            );
        }
        let classes = rem / (hidden + 1);
        if classes < 2 {
            bail!("inferred classes {classes} < 2");
        }
        Ok(Self {
            batch,
            in_dim,
            hidden,
            classes,
        })
    }
}

fn dims_len(dims: &MlpDims) -> usize {
    dims.in_dim * dims.hidden + dims.hidden + dims.hidden * dims.classes + dims.classes
}

/// Borrowed views into the flat parameter vector (no copies — the
/// reference path used to `to_vec` all four segments on every call).
fn split_params<'a>(
    dims: &MlpDims,
    params: &'a [f32],
) -> (&'a [f32], &'a [f32], &'a [f32], &'a [f32]) {
    let (w1, rest) = params.split_at(dims.in_dim * dims.hidden);
    let (b1, rest) = rest.split_at(dims.hidden);
    let (w2, b2) = rest.split_at(dims.hidden * dims.classes);
    (w1, b1, w2, b2)
}

fn quantizer_for(variant: &str) -> Result<Option<GradQuantizer>> {
    match variant {
        "exact" | "qat" => Ok(None),
        v => match GradQuantizer::from_name(v) {
            Some(q) => Ok(Some(q)),
            None => bail!("native backend: unknown variant `{v}`"),
        },
    }
}

/// Extract the single element of a scalar f32 lane, naming the lane in
/// the error — an empty or multi-element tensor used to panic on `[0]`.
fn scalar_f32(t: &HostTensor, lane: &str) -> Result<f32> {
    let v = t.as_f32()?;
    match v {
        [x] => Ok(*x),
        _ => bail!(
            "expected a scalar f32 tensor for `{lane}`, got {} elements",
            v.len()
        ),
    }
}

/// Validate the label lane: int32 with exactly `batch` entries.
fn labels<'a>(t: &'a HostTensor, batch: usize) -> Result<&'a [i32]> {
    match t {
        HostTensor::I32(v) if v.len() == batch => Ok(v),
        HostTensor::I32(v) => bail!("expected {batch} int32 labels, got {}", v.len()),
        HostTensor::F32(_) => bail!("expected int32 labels, got an f32 tensor"),
    }
}

fn check_x(dims: &MlpDims, x: &[f32]) -> Result<()> {
    let want = dims.batch * dims.in_dim;
    if x.len() != want {
        bail!(
            "input x has {} elements, expected batch {} x in_dim {}",
            x.len(),
            dims.batch,
            dims.in_dim
        );
    }
    Ok(())
}

/// The seed lane is a *bit-pattern carrier*: callers may pack a full u32
/// (`f32::from_bits`) or pass a small integral float — either way the
/// raw bits key the SR noise stream, so distinct bit patterns give
/// independent draws and equal patterns replay exactly.
fn seed_rng(seed: f32) -> Pcg32 {
    Pcg32::new(u64::from(seed.to_bits()), 1013)
}

// ---------------------------------------------------------------------
// Workspace arena (blocked path)
// ---------------------------------------------------------------------

struct WsMetrics {
    flops: Counter,
    grows: Counter,
    bytes: Gauge,
}

/// Reusable per-thread buffers for the blocked step path. `resize` never
/// shrinks a `Vec`'s capacity, so after the first step at a given
/// geometry every `prepare` call is allocation-free; the grow counter
/// below stays flat once the arena is warm (geometry churn shows up as
/// increments).
#[derive(Default)]
struct Workspace {
    h_pre: Vec<f32>,
    h: Vec<f32>,
    logits: Mat,
    probs: Mat,
    g: Mat,
    gq: Mat,
    g_h: Mat,
    g_hq: Mat,
    w2t: Vec<f32>,
    grad: Vec<f32>,
    scratch: FusedScratch,
    // Integer-path lanes (`--compute int8`): i8 code matrices + affine
    // scales for the two gradient signals and the three det-quantized
    // GEMM operands, plus the i16/i32 packing scratch the `gemm_i8*`
    // kernels own. All stay capacity-zero in simulate mode, so the
    // simulate-mode `native_ws_bytes` value is unchanged.
    int_gemm: kernels::IntGemmScratch,
    g_codes: CodeMat,
    g_scales: CodeScales,
    gh_codes: CodeMat,
    gh_scales: CodeScales,
    h_codes: CodeMat,
    h_scales: CodeScales,
    x_codes: CodeMat,
    x_scales: CodeScales,
    w2_codes: CodeMat,
    w2_scales: CodeScales,
    high_water: usize,
    metrics: Option<WsMetrics>,
}

impl Workspace {
    /// Bytes held by the integer-path code/panel lanes (zero until the
    /// first `--compute int8` step on this thread).
    fn int_bytes(&self) -> usize {
        self.int_gemm.bytes()
            + self.g_codes.data.capacity()
            + self.gh_codes.data.capacity()
            + self.h_codes.data.capacity()
            + self.x_codes.data.capacity()
            + self.w2_codes.data.capacity()
    }

    fn prepare(&mut self, dims: &MlpDims) {
        let (b, h, c) = (dims.batch, dims.hidden, dims.classes);
        self.h_pre.resize(b * h, 0.0);
        self.h.resize(b * h, 0.0);
        self.logits.resize(b, c);
        self.probs.resize(b, c);
        self.g.resize(b, c);
        self.gq.resize(b, c);
        self.g_h.resize(b, h);
        self.g_hq.resize(b, h);
        self.w2t.resize(h * c, 0.0);
        self.grad.resize(dims_len(dims), 0.0);
        if self.metrics.is_none() && crate::obs::enabled() {
            let m = crate::obs::metrics();
            self.metrics = Some(WsMetrics {
                flops: m.counter(
                    "native_kernel_flops_total",
                    "f32 FLOPs executed by the blocked native kernel layer",
                ),
                grows: m.counter(
                    "native_ws_grow_total",
                    "workspace arena growth events (should stay flat once warm; \
                     increments mean geometry churn is re-allocating)",
                ),
                bytes: m.gauge(
                    "native_ws_bytes",
                    "per-thread native workspace high-water size in bytes",
                ),
            });
        }
        let f32_elems = 4 * b * h + 4 * b * c + h * c + dims_len(dims);
        let need = f32_elems * std::mem::size_of::<f32>() + self.int_bytes();
        if need > self.high_water {
            self.high_water = need;
            if let Some(m) = &self.metrics {
                m.grows.inc();
                m.bytes.set(need as f64);
            }
        }
    }
}

thread_local! {
    /// `NativeExecutor::execute` takes `&self` and runs concurrently on
    /// the data-parallel pool threads, so the arena is per thread rather
    /// than per executor.
    static WORKSPACE: RefCell<Workspace> = RefCell::new(Workspace::default());
}

fn forward_flops(dims: &MlpDims) -> u64 {
    let (b, d, h, c) = (
        dims.batch as u64,
        dims.in_dim as u64,
        dims.hidden as u64,
        dims.classes as u64,
    );
    2 * b * (d * h + h * c)
}

fn backward_flops(dims: &MlpDims) -> u64 {
    let (b, d, h, c) = (
        dims.batch as u64,
        dims.in_dim as u64,
        dims.hidden as u64,
        dims.classes as u64,
    );
    // dW2 (b·h·c) + g_a (b·c·h) + dW1 (b·d·h) multiply-adds
    2 * b * (d * h + 2 * h * c)
}

// ---------------------------------------------------------------------
// Blocked step path (default)
// ---------------------------------------------------------------------

/// Whole-batch forward through the blocked kernels, writing into the
/// workspace. Returns (mean loss, accuracy). Arithmetic is element-for-
/// element identical to `reference::forward`: the GEMMs preserve the
/// per-element accumulation order and the softmax loop is unchanged.
fn forward_blocked(
    dims: &MlpDims,
    params: &[f32],
    x: &[f32],
    y: &[i32],
    ws: &mut Workspace,
) -> Result<(f64, f64)> {
    let (w1, b1, w2, b2) = split_params(dims, params);
    let (bsz, h_dim, c_dim) = (dims.batch, dims.hidden, dims.classes);
    kernels::gemm(&mut ws.h_pre, Init::Bias(b1), x, w1, bsz, dims.in_dim, h_dim);
    kernels::relu(&mut ws.h, &ws.h_pre);
    kernels::gemm(&mut ws.logits.data, Init::Bias(b2), &ws.h, w2, bsz, h_dim, c_dim);

    // numerically stable softmax cross-entropy (kept separate from the
    // probs buffer: the argmax scan reads earlier logits while writing)
    let mut loss = 0.0f64;
    let mut correct = 0u64;
    for (i, &label) in y.iter().enumerate() {
        if label < 0 || label as usize >= c_dim {
            bail!("label {label} out of range [0, {c_dim})");
        }
        let logits = ws.logits.row(i);
        let m = logits.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
        let sum_exp: f64 = logits.iter().map(|&v| f64::from(v - m).exp()).sum();
        let lse = f64::from(m) + sum_exp.ln();
        loss += lse - f64::from(logits[label as usize]);
        let mut argmax = 0usize;
        for (c, (pv, &lv)) in ws.probs.row_mut(i).iter_mut().zip(logits).enumerate() {
            *pv = (f64::from(lv) - lse).exp() as f32;
            if lv > logits[argmax] {
                argmax = c;
            }
        }
        if argmax == label as usize {
            correct += 1;
        }
    }
    Ok((loss / bsz as f64, correct as f64 / bsz as f64))
}

/// Whole-batch backward through the blocked kernels. Consumes the
/// forward intermediates in the workspace and leaves the flat gradient
/// in `ws.grad` (parameter layout) and the actgrad tap in `ws.g_h`.
/// FQT variants run the quantizers' fused `apply_into` paths — same
/// math, same RNG draw order, zero allocation once warm.
fn backward_blocked(
    dims: &MlpDims,
    params: &[f32],
    x: &[f32],
    y: &[i32],
    quant: Option<(GradQuantizer, f32)>,
    rng: &mut Pcg32,
    ws: &mut Workspace,
) {
    let (_w1, _b1, w2, _b2) = split_params(dims, params);
    let (bsz, d_dim, h_dim, c_dim) = (dims.batch, dims.in_dim, dims.hidden, dims.classes);

    // G = (softmax - onehot) / batch, one sample per row.
    ws.g.data.copy_from_slice(&ws.probs.data);
    let inv_b = 1.0 / bsz as f32;
    for (i, &label) in y.iter().enumerate() {
        let row = ws.g.row_mut(i);
        row[label as usize] -= 1.0;
        for v in row.iter_mut() {
            *v *= inv_b;
        }
    }
    let g: &Mat = match quant {
        Some((q, bits)) => {
            q.apply_into(&ws.g, bits, rng, &mut ws.scratch, &mut ws.gq);
            &ws.gq
        }
        None => &ws.g,
    };

    let (dw1, rest) = ws.grad.split_at_mut(d_dim * h_dim);
    let (db1, rest) = rest.split_at_mut(h_dim);
    let (dw2, db2) = rest.split_at_mut(h_dim * c_dim);

    kernels::gemm_at_b(dw2, Init::Zero, &ws.h, &g.data, bsz, h_dim, c_dim);
    kernels::col_sums(db2, &g.data, c_dim);

    // g_a = G · W2ᵀ: materializing W2ᵀ keeps the contraction in the
    // ascending-k accumulation order of the reference dot products.
    kernels::transpose(&mut ws.w2t, w2, h_dim, c_dim);
    kernels::gemm(&mut ws.g_h.data, Init::Zero, &g.data, &ws.w2t, bsz, c_dim, h_dim);

    // relu mask at the tap
    kernels::relu_mask(&mut ws.g_h.data, &ws.h_pre);
    let gh: &Mat = match quant {
        Some((q, bits)) => {
            q.apply_into(&ws.g_h, bits, rng, &mut ws.scratch, &mut ws.g_hq);
            &ws.g_hq
        }
        None => &ws.g_h,
    };

    kernels::gemm_at_b(dw1, Init::Zero, x, &gh.data, bsz, d_dim, h_dim);
    kernels::col_sums(db1, &gh.data, h_dim);
}

/// Bin count for the deterministic 8-bit operand quantization of the
/// integer backward path (H, X, W2 — the non-gradient GEMM operands).
const OPERAND_NBINS: f32 = 255.0;

/// Integer-code backward — the `--compute int8` path. This is genuine
/// low-bitwidth training, not a simulation: the gradient signals come
/// out of [`GradQuantizer::quantize_codes`] as centered i8 codes (the
/// dequantized f32 signal is never materialized on the PTQ path), the
/// non-gradient operands (H, X, W2) are deterministically quantized to
/// 8-bit codes per step, and every eligible GEMM runs in the i8/i32
/// `kernels::gemm_i8*` family with the affine scales folded into the
/// f32 epilogue.
///
/// Scale-axis split (see DESIGN.md §5.1): PTQ's per-tensor scales fold
/// into every epilogue, so all three backward GEMMs and both bias
/// reductions run integer. PSQ's per-sample scales sit on the
/// *contraction* axis of the weight-gradient GEMMs (`HᵀG`, `Xᵀg_h`),
/// where a per-row scale cannot be hoisted out of the k-sum — those
/// stay on the f32 kernels over the dequantized signal (bitwise equal
/// to the simulate path), while the hidden-gradient GEMM `G·W2ᵀ` (scales
/// on the M axis) still runs integer.
///
/// Callers must gate on [`GradQuantizer::supports_codes`]; ineligible
/// quantizers/bitwidths take `backward_blocked` via [`backward_for`],
/// counted in `quant_int_fallback_total`.
fn backward_blocked_int8(
    dims: &MlpDims,
    params: &[f32],
    x: &[f32],
    y: &[i32],
    q: GradQuantizer,
    bits: f32,
    rng: &mut Pcg32,
    ws: &mut Workspace,
) {
    let (_w1, _b1, w2, _b2) = split_params(dims, params);
    let (bsz, d_dim, h_dim, c_dim) = (dims.batch, dims.in_dim, dims.hidden, dims.classes);

    // G = (softmax - onehot) / batch — identical to the simulate path.
    ws.g.data.copy_from_slice(&ws.probs.data);
    let inv_b = 1.0 / bsz as f32;
    for (i, &label) in y.iter().enumerate() {
        let row = ws.g.row_mut(i);
        row[label as usize] -= 1.0;
        for v in row.iter_mut() {
            *v *= inv_b;
        }
    }

    // Logit-gradient signal straight to codes (same RNG stream as the
    // fused simulate quantizers; PSQ also fills `ws.gq` with the
    // dequantized signal for its f32 weight-gradient kernels).
    let ok = q.quantize_codes(&ws.g, bits, rng, &mut ws.g_codes, &mut ws.g_scales, &mut ws.gq);
    debug_assert!(ok, "backward_for gates on supports_codes");

    // Deterministic 8-bit operand codes. W2's row-major (hidden x
    // classes) layout is already the Bᵀ panel `gemm_i8` contracts
    // against, so the integer path needs no transpose pass at all.
    ptq::quantize_det_codes_into(&ws.h, bsz, h_dim, OPERAND_NBINS, &mut ws.h_codes, &mut ws.h_scales);
    ptq::quantize_det_codes_into(w2, h_dim, c_dim, OPERAND_NBINS, &mut ws.w2_codes, &mut ws.w2_scales);

    let (dw1, rest) = ws.grad.split_at_mut(d_dim * h_dim);
    let (db1, rest) = rest.split_at_mut(h_dim);
    let (dw2, db2) = rest.split_at_mut(h_dim * c_dim);

    // Per-tensor gradient scales (PTQ) fold through AᵀB; per-sample
    // scales (PSQ) cannot — they live on the contraction axis.
    let per_tensor = !ws.g_scales.per_row;
    if per_tensor {
        // dW2 = Hᵀ·G — all-integer.
        kernels::gemm_i8_at_b(
            dw2,
            Init::Zero,
            &ws.h_codes.data,
            &ws.h_scales.inv,
            &ws.h_scales.zero,
            &ws.g_codes.data,
            &ws.g_scales.inv,
            &ws.g_scales.zero,
            bsz,
            h_dim,
            c_dim,
            &mut ws.int_gemm,
        );
        kernels::col_sums_i8(db2, &ws.g_codes.data, c_dim, ws.g_scales.inv[0], ws.g_scales.zero[0]);
    } else {
        kernels::gemm_at_b(dw2, Init::Zero, &ws.h, &ws.gq.data, bsz, h_dim, c_dim);
        kernels::col_sums(db2, &ws.gq.data, c_dim);
    }

    // g_a = G·W2ᵀ — integer for both PTQ and PSQ: the gradient scales
    // sit on the M (sample) axis and the operand scale is per-tensor,
    // so both fold into the epilogue.
    kernels::gemm_i8(
        &mut ws.g_h.data,
        Init::Zero,
        &ws.g_codes.data,
        &ws.g_scales.inv,
        &ws.g_scales.zero,
        &ws.w2_codes.data,
        &ws.w2_scales.inv,
        &ws.w2_scales.zero,
        bsz,
        h_dim,
        c_dim,
        &mut ws.int_gemm,
    );

    // relu mask at the tap, then the hidden-gradient signal to codes.
    kernels::relu_mask(&mut ws.g_h.data, &ws.h_pre);
    let ok = q.quantize_codes(&ws.g_h, bits, rng, &mut ws.gh_codes, &mut ws.gh_scales, &mut ws.g_hq);
    debug_assert!(ok, "backward_for gates on supports_codes");

    if per_tensor {
        ptq::quantize_det_codes_into(x, bsz, d_dim, OPERAND_NBINS, &mut ws.x_codes, &mut ws.x_scales);
        // dW1 = Xᵀ·g_h — all-integer.
        kernels::gemm_i8_at_b(
            dw1,
            Init::Zero,
            &ws.x_codes.data,
            &ws.x_scales.inv,
            &ws.x_scales.zero,
            &ws.gh_codes.data,
            &ws.gh_scales.inv,
            &ws.gh_scales.zero,
            bsz,
            d_dim,
            h_dim,
            &mut ws.int_gemm,
        );
        kernels::col_sums_i8(db1, &ws.gh_codes.data, h_dim, ws.gh_scales.inv[0], ws.gh_scales.zero[0]);
    } else {
        kernels::gemm_at_b(dw1, Init::Zero, x, &ws.g_hq.data, bsz, d_dim, h_dim);
        kernels::col_sums(db1, &ws.g_hq.data, h_dim);
    }
}

/// Route one backward pass by compute mode. Int8 requires an FQT
/// variant whose quantizer has an integer entry point at this bitwidth;
/// everything else (exact/qat, BHQ/FP8/BFP, fractional or >8 bits)
/// takes the simulate path — quantized variants with a counted
/// `quant_int_fallback_total` increment.
#[allow(clippy::too_many_arguments)]
fn backward_for(
    compute: ComputeMode,
    dims: &MlpDims,
    params: &[f32],
    x: &[f32],
    y: &[i32],
    quant: Option<(GradQuantizer, f32)>,
    rng: &mut Pcg32,
    ws: &mut Workspace,
) {
    match (compute, quant) {
        (ComputeMode::Int8, Some((q, bits))) if q.supports_codes(bits) => {
            backward_blocked_int8(dims, params, x, y, q, bits, rng, ws);
        }
        (ComputeMode::Int8, Some((q, _))) => {
            crate::obs::quant::int_fallback(q.name());
            backward_blocked(dims, params, x, y, quant, rng, ws);
        }
        _ => backward_blocked(dims, params, x, y, quant, rng, ws),
    }
}

/// (params, momentum, x, y, seed, lr, bits) -> (params', momentum', loss, acc)
fn train_step(
    meta: &ArtifactMeta,
    dims: &MlpDims,
    inputs: &[HostTensor],
    compute: ComputeMode,
) -> Result<StepOutputs> {
    let params = inputs[0].as_f32()?;
    let velocity = inputs[1].as_f32()?;
    let x = inputs[2].as_f32()?;
    let y = labels(&inputs[3], dims.batch)?;
    let seed = scalar_f32(&inputs[4], "seed")?;
    let lr = f64::from(scalar_f32(&inputs[5], "lr")?);
    let bits = scalar_f32(&inputs[6], "bits")?;
    check_x(dims, x)?;
    let quant = quantizer_for(&meta.variant)?.map(|q| (q, bits));

    WORKSPACE.with(|cell| {
        let ws = &mut *cell.borrow_mut();
        ws.prepare(dims);
        let (loss, acc) = {
            let _sp = crate::obs::span("native/forward");
            forward_blocked(dims, params, x, y, ws)?
        };
        let mut rng = seed_rng(seed);
        {
            let _sp = crate::obs::span("native/backward");
            backward_for(compute, dims, params, x, y, quant, &mut rng, ws);
        }
        if let Some(m) = &ws.metrics {
            m.flops.add(forward_flops(dims) + backward_flops(dims));
        }

        let mu = meta.momentum;
        let mut new_params = params.to_vec();
        let mut new_velocity = velocity.to_vec();
        for ((pv, vv), &g) in new_params
            .iter_mut()
            .zip(new_velocity.iter_mut())
            .zip(&ws.grad)
        {
            *vv = (mu * f64::from(*vv) + f64::from(g)) as f32;
            *pv = (f64::from(*pv) - lr * f64::from(*vv)) as f32;
        }
        Ok(vec![
            HostTensor::F32(new_params),
            HostTensor::F32(new_velocity),
            HostTensor::F32(vec![loss as f32]),
            HostTensor::F32(vec![acc as f32]),
        ])
    })
}

/// (params, x, y, seed, bits) -> (loss, flat_grad)
fn probe_step(
    meta: &ArtifactMeta,
    dims: &MlpDims,
    inputs: &[HostTensor],
    compute: ComputeMode,
) -> Result<StepOutputs> {
    let params = inputs[0].as_f32()?;
    let x = inputs[1].as_f32()?;
    let y = labels(&inputs[2], dims.batch)?;
    let seed = scalar_f32(&inputs[3], "seed")?;
    let bits = scalar_f32(&inputs[4], "bits")?;
    check_x(dims, x)?;
    let quant = quantizer_for(&meta.variant)?.map(|q| (q, bits));

    WORKSPACE.with(|cell| {
        let ws = &mut *cell.borrow_mut();
        ws.prepare(dims);
        let (loss, _acc) = {
            let _sp = crate::obs::span("native/forward");
            forward_blocked(dims, params, x, y, ws)?
        };
        let mut rng = seed_rng(seed);
        {
            let _sp = crate::obs::span("native/backward");
            backward_for(compute, dims, params, x, y, quant, &mut rng, ws);
        }
        if let Some(m) = &ws.metrics {
            m.flops.add(forward_flops(dims) + backward_flops(dims));
        }
        Ok(vec![
            HostTensor::F32(vec![loss as f32]),
            HostTensor::F32(ws.grad.clone()),
        ])
    })
}

/// (params, x, y) -> (loss, acc) — deterministic.
fn eval_step(dims: &MlpDims, inputs: &[HostTensor]) -> Result<StepOutputs> {
    let params = inputs[0].as_f32()?;
    let x = inputs[1].as_f32()?;
    let y = labels(&inputs[2], dims.batch)?;
    check_x(dims, x)?;
    WORKSPACE.with(|cell| {
        let ws = &mut *cell.borrow_mut();
        ws.prepare(dims);
        let (loss, acc) = forward_blocked(dims, params, x, y, ws)?;
        if let Some(m) = &ws.metrics {
            m.flops.add(forward_flops(dims));
        }
        Ok(vec![
            HostTensor::F32(vec![loss as f32]),
            HostTensor::F32(vec![acc as f32]),
        ])
    })
}

/// (params, x, y, seed) -> hidden-layer gradient tap (batch x hidden).
fn actgrad_step(dims: &MlpDims, inputs: &[HostTensor]) -> Result<StepOutputs> {
    let params = inputs[0].as_f32()?;
    let x = inputs[1].as_f32()?;
    let y = labels(&inputs[2], dims.batch)?;
    let seed = scalar_f32(&inputs[3], "seed")?;
    check_x(dims, x)?;
    WORKSPACE.with(|cell| {
        let ws = &mut *cell.borrow_mut();
        ws.prepare(dims);
        forward_blocked(dims, params, x, y, ws)?;
        let mut rng = seed_rng(seed);
        backward_blocked(dims, params, x, y, None, &mut rng, ws);
        if let Some(m) = &ws.metrics {
            m.flops.add(forward_flops(dims) + backward_flops(dims));
        }
        Ok(vec![HostTensor::F32(ws.g_h.data.clone())])
    })
}

// ---------------------------------------------------------------------
// Executor
// ---------------------------------------------------------------------

/// Which implementation of the step math to run.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KernelPath {
    /// Cache-blocked batched kernels + workspace arena (the default).
    #[default]
    Blocked,
    /// The retained per-sample interpreter — the golden reference the
    /// parity tests and the `native_step_speedup` bench compare against.
    Reference,
}

/// Arithmetic mode for the backward GEMMs (the forward pass is always
/// f32 — the paper quantizes the gradient signal, not inference).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ComputeMode {
    /// Quantize–dequantize simulation: the kernels multiply f32 values
    /// that happen to lie on the quantization grid (the default, and
    /// the mode every result before this knob was measured in).
    #[default]
    Simulate,
    /// True integer path: eligible backward GEMMs consume centered i8
    /// codes with i32 accumulation (`kernels::gemm_i8*`) and fold the
    /// affine scales into the f32 epilogue. Quantizers or bitwidths
    /// without an integer entry point fall back to `Simulate`, counted
    /// in `quant_int_fallback_total`.
    Int8,
}

impl ComputeMode {
    pub fn name(self) -> &'static str {
        match self {
            ComputeMode::Simulate => "simulate",
            ComputeMode::Int8 => "int8",
        }
    }

    pub fn from_name(name: &str) -> Option<Self> {
        match name {
            "simulate" => Some(ComputeMode::Simulate),
            "int8" => Some(ComputeMode::Int8),
            _ => None,
        }
    }
}

/// Stateless interpreter for the `mlp` artifacts. One instance per
/// [`Executor`](super::Executor); dispatch is on the artifact metadata.
#[derive(Clone, Copy, Debug, Default)]
pub struct NativeExecutor {
    path: KernelPath,
    compute: ComputeMode,
}

impl NativeExecutor {
    pub fn new(path: KernelPath) -> Self {
        Self {
            path,
            compute: ComputeMode::default(),
        }
    }

    /// The golden-reference (pre-kernel-layer) interpreter.
    pub fn reference() -> Self {
        Self::new(KernelPath::Reference)
    }

    /// Select the backward arithmetic mode. Only the blocked path has
    /// integer kernels; the reference interpreter ignores this and
    /// always simulates.
    #[must_use]
    pub fn with_compute(mut self, compute: ComputeMode) -> Self {
        self.compute = compute;
        self
    }
}

impl ExecutorBackend for NativeExecutor {
    fn name(&self) -> &'static str {
        match self.path {
            KernelPath::Blocked => "native",
            KernelPath::Reference => "native-reference",
        }
    }

    fn execute(&self, meta: &ArtifactMeta, inputs: &[HostTensor]) -> Result<StepOutputs> {
        let dims = MlpDims::infer(meta)?;
        match self.path {
            KernelPath::Blocked => match meta.step {
                StepKind::Train => train_step(meta, &dims, inputs, self.compute),
                StepKind::Probe => probe_step(meta, &dims, inputs, self.compute),
                StepKind::Eval => eval_step(&dims, inputs),
                StepKind::ActGrad => actgrad_step(&dims, inputs),
            },
            KernelPath::Reference => match meta.step {
                StepKind::Train => reference::train_step(meta, &dims, inputs),
                StepKind::Probe => reference::probe_step(meta, &dims, inputs),
                StepKind::Eval => reference::eval_step(&dims, inputs),
                StepKind::ActGrad => reference::actgrad_step(&dims, inputs),
            },
        }
    }
}

// ---------------------------------------------------------------------
// Reference step path
// ---------------------------------------------------------------------

/// The original per-sample interpreter, kept as the golden reference for
/// the blocked kernel path: allocating `split_params` copies, per-sample
/// triple loops, and the allocating quantizer `apply`. The parity
/// harness holds the two paths bitwise equal; the train-step bench
/// reports their latency ratio as `native_step_speedup`.
mod reference {
    use super::*;

    /// Cached intermediates of one forward pass.
    pub(super) struct Forward {
        /// Pre-activation of the hidden layer (batch x hidden) — the relu
        /// mask for the backward pass and the activation-gradient tap.
        pub(super) h_pre: Mat,
        /// Post-relu hidden activations (batch x hidden).
        pub(super) h: Mat,
        /// Softmax probabilities (batch x classes).
        pub(super) probs: Mat,
        pub(super) loss: f64,
        pub(super) acc: f64,
    }

    fn split_params(dims: &MlpDims, params: &[f32]) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let (w1, rest) = params.split_at(dims.in_dim * dims.hidden);
        let (b1, rest) = rest.split_at(dims.hidden);
        let (w2, b2) = rest.split_at(dims.hidden * dims.classes);
        (w1.to_vec(), b1.to_vec(), w2.to_vec(), b2.to_vec())
    }

    pub(super) fn forward(
        dims: &MlpDims,
        params: &[f32],
        x: &[f32],
        y: &[i32],
    ) -> Result<Forward> {
        let (w1, b1, w2, b2) = split_params(dims, params);
        let (bsz, h_dim, c_dim) = (dims.batch, dims.hidden, dims.classes);
        let mut h_pre = Mat::zeros(bsz, h_dim);
        let mut h = Mat::zeros(bsz, h_dim);
        let mut probs = Mat::zeros(bsz, c_dim);
        let mut loss = 0.0f64;
        let mut correct = 0u64;
        for i in 0..bsz {
            let label = y[i];
            if label < 0 || label as usize >= c_dim {
                bail!("label {label} out of range [0, {c_dim})");
            }
            let xi = &x[i * dims.in_dim..(i + 1) * dims.in_dim];
            let hp = h_pre.row_mut(i);
            hp.copy_from_slice(&b1);
            for (&xv, w1_row) in xi.iter().zip(w1.chunks(h_dim)) {
                for (o, &w) in hp.iter_mut().zip(w1_row) {
                    *o += xv * w;
                }
            }
            let hr = h.row_mut(i);
            for (a, &p) in hr.iter_mut().zip(h_pre.row(i)) {
                *a = p.max(0.0);
            }
            let mut logits = b2.clone();
            for (&hv, w2_row) in h.row(i).iter().zip(w2.chunks(c_dim)) {
                for (o, &w) in logits.iter_mut().zip(w2_row) {
                    *o += hv * w;
                }
            }
            // numerically stable softmax cross-entropy
            let m = logits.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
            let sum_exp: f64 = logits.iter().map(|&v| f64::from(v - m).exp()).sum();
            let lse = f64::from(m) + sum_exp.ln();
            loss += lse - f64::from(logits[label as usize]);
            let mut argmax = 0usize;
            for (c, (pv, &lv)) in probs.row_mut(i).iter_mut().zip(&logits).enumerate() {
                *pv = (f64::from(lv) - lse).exp() as f32;
                if lv > logits[argmax] {
                    argmax = c;
                }
            }
            if argmax == label as usize {
                correct += 1;
            }
        }
        Ok(Forward {
            h_pre,
            h,
            probs,
            loss: loss / bsz as f64,
            acc: correct as f64 / bsz as f64,
        })
    }

    /// Backward pass. FQT variants pass `Some((quantizer, bits))`, which
    /// quantizes the logit-gradient and hidden-gradient matrices with SR
    /// (unbiased, per Theorem 1). Returns the flat gradient in parameter
    /// layout plus the (post-relu-mask, pre-quantization) hidden
    /// gradient — the actgrad tap.
    pub(super) fn backward(
        dims: &MlpDims,
        params: &[f32],
        x: &[f32],
        fwd: &Forward,
        y: &[i32],
        quant: Option<(GradQuantizer, f32)>,
        rng: &mut Pcg32,
    ) -> (Vec<f32>, Mat) {
        let (bsz, d_dim, h_dim, c_dim) = (dims.batch, dims.in_dim, dims.hidden, dims.classes);
        let (_w1, _b1, w2, _b2) = split_params(dims, params);

        // G = (softmax - onehot) / batch, one sample per row.
        let mut g = fwd.probs.clone();
        let inv_b = 1.0 / bsz as f32;
        for (i, &label) in y.iter().enumerate() {
            let row = g.row_mut(i);
            row[label as usize] -= 1.0;
            for v in row.iter_mut() {
                *v *= inv_b;
            }
        }
        let g = match quant {
            Some((q, bits)) => q.apply(&g, bits, rng),
            None => g,
        };

        let mut dw2 = vec![0.0f32; h_dim * c_dim];
        let mut db2 = vec![0.0f32; c_dim];
        let mut g_a = Mat::zeros(bsz, h_dim);
        for i in 0..bsz {
            let gi = g.row(i);
            for (&hv, dw2_row) in fwd.h.row(i).iter().zip(dw2.chunks_mut(c_dim)) {
                for (o, &gv) in dw2_row.iter_mut().zip(gi) {
                    *o += hv * gv;
                }
            }
            for (o, &gv) in db2.iter_mut().zip(gi) {
                *o += gv;
            }
            for (o, w2_row) in g_a.row_mut(i).iter_mut().zip(w2.chunks(c_dim)) {
                *o = w2_row.iter().zip(gi).map(|(&w, &gv)| w * gv).sum();
            }
        }

        // relu mask at the tap
        let mut g_h = g_a;
        for (v, &p) in g_h.data.iter_mut().zip(&fwd.h_pre.data) {
            if p <= 0.0 {
                *v = 0.0;
            }
        }
        let g_hq = match quant {
            Some((q, bits)) => q.apply(&g_h, bits, rng),
            None => g_h.clone(),
        };

        let mut dw1 = vec![0.0f32; d_dim * h_dim];
        let mut db1 = vec![0.0f32; h_dim];
        for i in 0..bsz {
            let gi = g_hq.row(i);
            let xi = &x[i * d_dim..(i + 1) * d_dim];
            for (&xv, dw1_row) in xi.iter().zip(dw1.chunks_mut(h_dim)) {
                for (o, &gv) in dw1_row.iter_mut().zip(gi) {
                    *o += xv * gv;
                }
            }
            for (o, &gv) in db1.iter_mut().zip(gi) {
                *o += gv;
            }
        }

        let mut grad = Vec::with_capacity(dims_len(dims));
        grad.extend_from_slice(&dw1);
        grad.extend_from_slice(&db1);
        grad.extend_from_slice(&dw2);
        grad.extend_from_slice(&db2);
        (grad, g_h)
    }

    /// (params, momentum, x, y, seed, lr, bits) -> (params', momentum', loss, acc)
    pub(super) fn train_step(
        meta: &ArtifactMeta,
        dims: &MlpDims,
        inputs: &[HostTensor],
    ) -> Result<StepOutputs> {
        let params = inputs[0].as_f32()?;
        let mut velocity = inputs[1].as_f32()?.to_vec();
        let x = inputs[2].as_f32()?;
        let y = labels(&inputs[3], dims.batch)?;
        let seed = scalar_f32(&inputs[4], "seed")?;
        let lr = f64::from(scalar_f32(&inputs[5], "lr")?);
        let bits = scalar_f32(&inputs[6], "bits")?;
        check_x(dims, x)?;

        let fwd = {
            let _sp = crate::obs::span("native/forward");
            forward(dims, params, x, y)?
        };
        let quant = quantizer_for(&meta.variant)?.map(|q| (q, bits));
        let mut rng = seed_rng(seed);
        let (grad, _) = {
            let _sp = crate::obs::span("native/backward");
            backward(dims, params, x, &fwd, y, quant, &mut rng)
        };

        let mu = meta.momentum;
        let mut new_params = params.to_vec();
        for ((pv, vv), &g) in new_params.iter_mut().zip(velocity.iter_mut()).zip(&grad) {
            *vv = (mu * f64::from(*vv) + f64::from(g)) as f32;
            *pv = (f64::from(*pv) - lr * f64::from(*vv)) as f32;
        }
        Ok(vec![
            HostTensor::F32(new_params),
            HostTensor::F32(velocity),
            HostTensor::F32(vec![fwd.loss as f32]),
            HostTensor::F32(vec![fwd.acc as f32]),
        ])
    }

    /// (params, x, y, seed, bits) -> (loss, flat_grad)
    pub(super) fn probe_step(
        meta: &ArtifactMeta,
        dims: &MlpDims,
        inputs: &[HostTensor],
    ) -> Result<StepOutputs> {
        let params = inputs[0].as_f32()?;
        let x = inputs[1].as_f32()?;
        let y = labels(&inputs[2], dims.batch)?;
        let seed = scalar_f32(&inputs[3], "seed")?;
        let bits = scalar_f32(&inputs[4], "bits")?;
        check_x(dims, x)?;

        let fwd = {
            let _sp = crate::obs::span("native/forward");
            forward(dims, params, x, y)?
        };
        let quant = quantizer_for(&meta.variant)?.map(|q| (q, bits));
        let mut rng = seed_rng(seed);
        let (grad, _) = {
            let _sp = crate::obs::span("native/backward");
            backward(dims, params, x, &fwd, y, quant, &mut rng)
        };
        Ok(vec![
            HostTensor::F32(vec![fwd.loss as f32]),
            HostTensor::F32(grad),
        ])
    }

    /// (params, x, y) -> (loss, acc) — deterministic.
    pub(super) fn eval_step(dims: &MlpDims, inputs: &[HostTensor]) -> Result<StepOutputs> {
        let params = inputs[0].as_f32()?;
        let x = inputs[1].as_f32()?;
        let y = labels(&inputs[2], dims.batch)?;
        check_x(dims, x)?;
        let fwd = forward(dims, params, x, y)?;
        Ok(vec![
            HostTensor::F32(vec![fwd.loss as f32]),
            HostTensor::F32(vec![fwd.acc as f32]),
        ])
    }

    /// (params, x, y, seed) -> hidden-layer gradient tap (batch x hidden).
    pub(super) fn actgrad_step(dims: &MlpDims, inputs: &[HostTensor]) -> Result<StepOutputs> {
        let params = inputs[0].as_f32()?;
        let x = inputs[1].as_f32()?;
        let y = labels(&inputs[2], dims.batch)?;
        check_x(dims, x)?;
        let fwd = forward(dims, params, x, y)?;
        let mut rng = seed_rng(scalar_f32(&inputs[3], "seed")?);
        let (_, g_h) = backward(dims, params, x, &fwd, y, None, &mut rng);
        Ok(vec![HostTensor::F32(g_h.data)])
    }
}

// ---------------------------------------------------------------------
// Artifact generation
// ---------------------------------------------------------------------

fn tensor_json(shape: &[usize], dtype: &str) -> Json {
    obj([
        ("shape", shape.iter().copied().collect::<Json>()),
        ("dtype", Json::from(dtype)),
    ])
}

fn abi(spec: &MlpSpec, step: StepKind) -> (Vec<Json>, Vec<Json>) {
    let n = spec.n_params();
    let params = || tensor_json(&[n], "float32");
    let xs = || tensor_json(&[spec.batch, spec.in_dim], "float32");
    let ys = || tensor_json(&[spec.batch], "int32");
    let scalar = || tensor_json(&[], "float32");
    match step {
        StepKind::Train => (
            vec![params(), params(), xs(), ys(), scalar(), scalar(), scalar()],
            vec![params(), params(), scalar(), scalar()],
        ),
        StepKind::Probe => (
            vec![params(), xs(), ys(), scalar(), scalar()],
            vec![scalar(), params()],
        ),
        StepKind::Eval => (vec![params(), xs(), ys()], vec![scalar(), scalar()]),
        StepKind::ActGrad => (
            vec![params(), xs(), ys(), scalar()],
            vec![tensor_json(&[spec.batch, spec.hidden], "float32")],
        ),
    }
}

/// The ABI metadata [`write_artifacts`] would emit for `spec`, without
/// touching the filesystem — the entry point the bench harness and the
/// parity tests use to drive the backend directly.
pub fn meta_for(spec: &MlpSpec, variant: &str, step: StepKind) -> ArtifactMeta {
    ArtifactMeta {
        model: "mlp".into(),
        variant: variant.into(),
        step,
        n_params: spec.n_params(),
        batch: spec.batch,
        input_shape: vec![spec.batch, spec.in_dim],
        input_dtype: "float32".into(),
        inputs: vec![],
        outputs: vec![],
        probe_shape: vec![spec.batch, spec.hidden],
        momentum: 0.9,
        hlo_path: std::path::PathBuf::from("native.hlo.txt"),
    }
}

fn write_sidecar(dir: &Path, spec: &MlpSpec, variant: &str, step: StepKind) -> Result<()> {
    let (inputs, outputs) = abi(spec, step);
    let j = obj([
        ("model", Json::from("mlp")),
        ("variant", Json::from(variant)),
        ("step", Json::from(step.name())),
        ("n_params", Json::from(spec.n_params())),
        ("batch", Json::from(spec.batch)),
        (
            "input_shape",
            [spec.batch, spec.in_dim].into_iter().collect::<Json>(),
        ),
        ("input_dtype", Json::from("float32")),
        ("inputs", inputs.into_iter().collect::<Json>()),
        ("outputs", outputs.into_iter().collect::<Json>()),
        (
            "probe_shape",
            [spec.batch, spec.hidden].into_iter().collect::<Json>(),
        ),
        ("momentum", Json::from(0.9)),
    ]);
    let stem = format!("mlp_{variant}_{}", step.name());
    std::fs::write(dir.join(format!("{stem}.json")), j.to_string_pretty())
        .with_context(|| format!("writing {stem}.json"))?;
    std::fs::write(
        dir.join(format!("{stem}.hlo.txt")),
        "// placeholder module: this artifact executes on the native interpreter\n\
         // backend. Run the Python AOT pipeline to lower real HLO for PJRT.\n",
    )
    .with_context(|| format!("writing {stem}.hlo.txt"))?;
    Ok(())
}

/// He-initialised flat parameter vector for the spec's MLP.
pub fn init_params(spec: &MlpSpec) -> Vec<f32> {
    let mut rng = Pcg32::new(spec.seed, 77);
    let mut params = vec![0.0f32; spec.n_params()];
    let (w1_end, b1_end) = (
        spec.in_dim * spec.hidden,
        spec.in_dim * spec.hidden + spec.hidden,
    );
    let w2_end = b1_end + spec.hidden * spec.classes;
    let s1 = (2.0 / spec.in_dim as f32).sqrt();
    for v in &mut params[..w1_end] {
        *v = rng.normal() * s1;
    }
    let s2 = (2.0 / spec.hidden as f32).sqrt();
    for v in &mut params[b1_end..w2_end] {
        *v = rng.normal() * s2;
    }
    params
}

/// Write a complete native `mlp` artifact set into `dir`: train + probe
/// sidecars for every variant in [`VARIANTS`], a `qat` eval and actgrad
/// step, placeholder HLO files, and the He-initialised `mlp_init.bin`.
pub fn write_artifacts(dir: &Path, spec: &MlpSpec) -> Result<()> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating artifact dir {}", dir.display()))?;
    let params = init_params(spec);
    let mut bytes = Vec::with_capacity(params.len() * 4);
    for v in &params {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    std::fs::write(dir.join("mlp_init.bin"), bytes).context("writing mlp_init.bin")?;
    for variant in VARIANTS {
        write_sidecar(dir, spec, variant, StepKind::Train)?;
        write_sidecar(dir, spec, variant, StepKind::Probe)?;
    }
    write_sidecar(dir, spec, "qat", StepKind::Eval)?;
    write_sidecar(dir, spec, "qat", StepKind::ActGrad)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::Registry;

    fn tiny_spec() -> MlpSpec {
        MlpSpec {
            in_dim: 5,
            hidden: 4,
            classes: 3,
            batch: 6,
            seed: 42,
        }
    }

    fn tiny_meta(variant: &str, step: StepKind) -> ArtifactMeta {
        meta_for(&tiny_spec(), variant, step)
    }

    fn tiny_batch(spec: &MlpSpec, seed: u64) -> (Vec<f32>, Vec<i32>) {
        let mut rng = Pcg32::new(seed, 3);
        let x: Vec<f32> = (0..spec.batch * spec.in_dim)
            .map(|_| rng.normal())
            .collect();
        let y: Vec<i32> = (0..spec.batch)
            .map(|_| rng.below(spec.classes as u32) as i32)
            .collect();
        (x, y)
    }

    #[test]
    fn dims_inference_recovers_spec() {
        let meta = tiny_meta("qat", StepKind::Probe);
        let dims = MlpDims::infer(&meta).unwrap();
        assert_eq!(
            dims,
            MlpDims {
                batch: 6,
                in_dim: 5,
                hidden: 4,
                classes: 3
            }
        );
        let mut bad = tiny_meta("qat", StepKind::Probe);
        bad.n_params += 1;
        assert!(MlpDims::infer(&bad).is_err());
        let mut cnn = tiny_meta("qat", StepKind::Probe);
        cnn.model = "cnn".into();
        assert!(MlpDims::infer(&cnn).is_err());
    }

    /// Central finite differences of the eval loss must match the
    /// deterministic probe gradient coordinate-by-coordinate.
    #[test]
    fn gradient_matches_finite_differences() {
        let spec = tiny_spec();
        let dims = MlpDims::infer(&tiny_meta("qat", StepKind::Probe)).unwrap();
        let params = init_params(&spec);
        let (x, y) = tiny_batch(&spec, 9);
        let fwd = reference::forward(&dims, &params, &x, &y).unwrap();
        let mut rng = Pcg32::new(0, 0);
        let (grad, _) = reference::backward(&dims, &params, &x, &fwd, &y, None, &mut rng);

        let eps = 1e-2f32;
        let mut fd = vec![0.0f64; params.len()];
        for (i, slot) in fd.iter_mut().enumerate() {
            let mut p = params.clone();
            p[i] = params[i] + eps;
            let up = reference::forward(&dims, &p, &x, &y).unwrap().loss;
            p[i] = params[i] - eps;
            let dn = reference::forward(&dims, &p, &x, &y).unwrap().loss;
            *slot = (up - dn) / (2.0 * f64::from(eps));
        }
        let num: f64 = fd
            .iter()
            .zip(&grad)
            .map(|(&a, &b)| (a - f64::from(b)).powi(2))
            .sum::<f64>()
            .sqrt();
        let den: f64 = grad
            .iter()
            .map(|&g| f64::from(g) * f64::from(g))
            .sum::<f64>()
            .sqrt();
        assert!(
            num < 1e-2 * den.max(1e-6),
            "finite-diff mismatch: ||fd-g|| = {num}, ||g|| = {den}"
        );
    }

    #[test]
    fn probe_is_seed_deterministic_and_seed_sensitive() {
        let spec = tiny_spec();
        let meta = tiny_meta("ptq", StepKind::Probe);
        let params = init_params(&spec);
        let (x, y) = tiny_batch(&spec, 4);
        let run = |seed: f32| {
            let inputs = [
                HostTensor::F32(params.clone()),
                HostTensor::F32(x.clone()),
                HostTensor::I32(y.clone()),
                HostTensor::F32(vec![seed]),
                HostTensor::F32(vec![4.0]),
            ];
            NativeExecutor::default()
                .execute(&meta, &inputs)
                .unwrap()
                .pop()
                .unwrap()
                .into_f32()
                .unwrap()
        };
        assert_eq!(run(3.0), run(3.0));
        assert_ne!(run(3.0), run(4.0));
    }

    /// Thm 1 through the interpreter: E[FQT grad] equals the exact grad.
    #[test]
    fn fqt_probe_mean_matches_exact_gradient() {
        let spec = tiny_spec();
        let dims = MlpDims::infer(&tiny_meta("qat", StepKind::Probe)).unwrap();
        let params = init_params(&spec);
        let (x, y) = tiny_batch(&spec, 11);
        let fwd = reference::forward(&dims, &params, &x, &y).unwrap();
        let mut rng0 = Pcg32::new(0, 0);
        let (g_ref, _) = reference::backward(&dims, &params, &x, &fwd, &y, None, &mut rng0);

        let seeds = 96;
        let mut mean = vec![0.0f64; params.len()];
        for k in 0..seeds {
            let mut rng = seed_rng(k as f32);
            let (g, _) = reference::backward(
                &dims,
                &params,
                &x,
                &fwd,
                &y,
                Some((GradQuantizer::Ptq, 4.0)),
                &mut rng,
            );
            for (m, &v) in mean.iter_mut().zip(&g) {
                *m += f64::from(v) / f64::from(seeds);
            }
        }
        let dot: f64 = mean.iter().zip(&g_ref).map(|(&a, &b)| a * f64::from(b)).sum();
        let na = mean.iter().map(|&a| a * a).sum::<f64>().sqrt();
        let nb = g_ref
            .iter()
            .map(|&b| f64::from(b) * f64::from(b))
            .sum::<f64>()
            .sqrt();
        let cos = dot / (na * nb).max(1e-30);
        assert!(cos > 0.95, "cos(E[fqt], exact) = {cos}");
    }

    #[test]
    fn train_step_updates_state_and_reports_finite_loss() {
        let spec = tiny_spec();
        let meta = tiny_meta("psq", StepKind::Train);
        let params = init_params(&spec);
        let (x, y) = tiny_batch(&spec, 21);
        let inputs = [
            HostTensor::F32(params.clone()),
            HostTensor::F32(vec![0.0; params.len()]),
            HostTensor::F32(x),
            HostTensor::I32(y),
            HostTensor::F32(vec![1.0]),
            HostTensor::F32(vec![0.1]),
            HostTensor::F32(vec![5.0]),
        ];
        let out = NativeExecutor::default().execute(&meta, &inputs).unwrap();
        assert_eq!(out.len(), 4);
        let new_params = out[0].as_f32().unwrap();
        assert_ne!(new_params, &params[..]);
        let loss = out[2].as_f32().unwrap()[0];
        assert!(loss.is_finite() && loss > 0.0);
        let acc = out[3].as_f32().unwrap()[0];
        assert!((0.0..=1.0).contains(&acc));
    }

    /// The blocked default path and the retained reference path must
    /// produce identical bits on a quantized train step (same math, same
    /// RNG draw order). The full matrix lives in `tests/kernel_parity.rs`.
    #[test]
    fn blocked_path_matches_reference_bitwise() {
        let spec = tiny_spec();
        let meta = tiny_meta("bhq", StepKind::Train);
        let params = init_params(&spec);
        let (x, y) = tiny_batch(&spec, 33);
        let inputs = [
            HostTensor::F32(params.clone()),
            HostTensor::F32(vec![0.0; params.len()]),
            HostTensor::F32(x),
            HostTensor::I32(y),
            HostTensor::F32(vec![7.0]),
            HostTensor::F32(vec![0.1]),
            HostTensor::F32(vec![4.0]),
        ];
        let a = NativeExecutor::default().execute(&meta, &inputs).unwrap();
        let b = NativeExecutor::reference().execute(&meta, &inputs).unwrap();
        for (ta, tb) in a.iter().zip(&b) {
            assert_eq!(ta.as_f32().unwrap(), tb.as_f32().unwrap());
        }
    }

    #[test]
    fn compute_mode_names_round_trip() {
        for m in [ComputeMode::Simulate, ComputeMode::Int8] {
            assert_eq!(ComputeMode::from_name(m.name()), Some(m));
        }
        assert_eq!(ComputeMode::from_name("fp64"), None);
        assert_eq!(ComputeMode::default(), ComputeMode::Simulate);
    }

    /// The int8 probe is bitwise reproducible across runs, its forward
    /// loss is bitwise equal to simulate (the forward pass is f32 in
    /// both modes), and its gradient tracks the simulate gradient — the
    /// two modes are different unbiased estimators of the same exact
    /// gradient (int8 additionally quantizes the GEMM operands), so the
    /// comparison is directional, not bitwise.
    #[test]
    fn int8_probe_reproducible_and_tracks_simulate() {
        let spec = tiny_spec();
        let meta = tiny_meta("ptq", StepKind::Probe);
        let params = init_params(&spec);
        let (x, y) = tiny_batch(&spec, 17);
        let run = |exec: NativeExecutor| {
            let inputs = [
                HostTensor::F32(params.clone()),
                HostTensor::F32(x.clone()),
                HostTensor::I32(y.clone()),
                HostTensor::F32(vec![5.0]),
                HostTensor::F32(vec![4.0]),
            ];
            let mut out = exec.execute(&meta, &inputs).unwrap();
            let grad = out.pop().unwrap().into_f32().unwrap();
            let loss = out.pop().unwrap().into_f32().unwrap()[0];
            (loss, grad)
        };
        let int8 = NativeExecutor::default().with_compute(ComputeMode::Int8);
        let (loss_a, grad_a) = run(int8);
        let (loss_b, grad_b) = run(int8);
        assert_eq!(loss_a.to_bits(), loss_b.to_bits());
        assert_eq!(grad_a, grad_b, "int8 path must be run-to-run bitwise");

        let (loss_s, grad_s) = run(NativeExecutor::default());
        assert_eq!(loss_a.to_bits(), loss_s.to_bits(), "forward is f32 in both modes");
        let dot: f64 = grad_a
            .iter()
            .zip(&grad_s)
            .map(|(&a, &b)| f64::from(a) * f64::from(b))
            .sum();
        let na = grad_a.iter().map(|&a| f64::from(a).powi(2)).sum::<f64>().sqrt();
        let ns = grad_s.iter().map(|&b| f64::from(b).powi(2)).sum::<f64>().sqrt();
        let cos = dot / (na * ns).max(1e-30);
        assert!(cos > 0.95, "cos(int8, simulate) = {cos}");
    }

    /// After the first int8 step at a geometry, the integer lanes stop
    /// growing: every later step reuses the arena capacity (the ISSUE 10
    /// allocation-free acceptance bullet, asserted on the arena itself
    /// rather than the racy global grow counter).
    #[test]
    fn int8_backward_is_allocation_free_after_warmup() {
        let spec = tiny_spec();
        let meta = tiny_meta("ptq", StepKind::Train);
        let dims = MlpDims::infer(&meta).unwrap();
        let params = init_params(&spec);
        let (x, y) = tiny_batch(&spec, 29);
        let mut ws = Workspace::default();
        let step = |ws: &mut Workspace, seed: u64| {
            ws.prepare(&dims);
            forward_blocked(&dims, &params, &x, &y, ws).unwrap();
            let mut rng = Pcg32::new(seed, 1);
            backward_blocked_int8(&dims, &params, &x, &y, GradQuantizer::Ptq, 4.0, &mut rng, ws);
        };
        step(&mut ws, 1);
        let warm = ws.int_bytes();
        assert!(warm > 0, "int lanes must be in use");
        let high_water = {
            ws.prepare(&dims); // fold the int lanes into the high-water mark
            ws.high_water
        };
        for s in 2..8 {
            step(&mut ws, s);
            assert_eq!(ws.int_bytes(), warm, "int lanes grew after warm-up");
        }
        ws.prepare(&dims);
        assert_eq!(ws.high_water, high_water, "arena grew after warm-up");
    }

    /// Quantizers/bitwidths without an integer entry point fall back to
    /// the simulate path bitwise: `--compute int8` never changes BHQ or
    /// fractional-bit numerics, it only counts the fallback.
    #[test]
    fn int8_falls_back_bitwise_for_unsupported_quantizers() {
        let spec = tiny_spec();
        let params = init_params(&spec);
        let (x, y) = tiny_batch(&spec, 41);
        for (variant, bits) in [("bhq", 4.0f32), ("ptq", 1.5), ("exact", 4.0)] {
            let meta = tiny_meta(variant, StepKind::Train);
            let inputs = [
                HostTensor::F32(params.clone()),
                HostTensor::F32(vec![0.0; params.len()]),
                HostTensor::F32(x.clone()),
                HostTensor::I32(y.clone()),
                HostTensor::F32(vec![7.0]),
                HostTensor::F32(vec![0.1]),
                HostTensor::F32(vec![bits]),
            ];
            let sim = NativeExecutor::default().execute(&meta, &inputs).unwrap();
            let int8 = NativeExecutor::default()
                .with_compute(ComputeMode::Int8)
                .execute(&meta, &inputs)
                .unwrap();
            for (ta, tb) in sim.iter().zip(&int8) {
                assert_eq!(
                    ta.as_f32().unwrap(),
                    tb.as_f32().unwrap(),
                    "{variant}@{bits}: fallback must be bitwise simulate"
                );
            }
        }
    }

    /// Regression (ISSUE 9 satellite): empty or wrong-arity scalar/label
    /// lanes must produce descriptive errors, not index panics.
    #[test]
    fn empty_or_wrong_arity_lanes_error_instead_of_panicking() {
        let spec = tiny_spec();
        let meta = tiny_meta("ptq", StepKind::Probe);
        let params = init_params(&spec);
        let (x, y) = tiny_batch(&spec, 4);
        for exec in [NativeExecutor::default(), NativeExecutor::reference()] {
            // empty seed lane
            let err = exec
                .execute(
                    &meta,
                    &[
                        HostTensor::F32(params.clone()),
                        HostTensor::F32(x.clone()),
                        HostTensor::I32(y.clone()),
                        HostTensor::F32(vec![]),
                        HostTensor::F32(vec![4.0]),
                    ],
                )
                .unwrap_err();
            assert!(format!("{err:#}").contains("seed"), "unhelpful: {err:#}");
            // two-element bits lane
            let err = exec
                .execute(
                    &meta,
                    &[
                        HostTensor::F32(params.clone()),
                        HostTensor::F32(x.clone()),
                        HostTensor::I32(y.clone()),
                        HostTensor::F32(vec![1.0]),
                        HostTensor::F32(vec![4.0, 5.0]),
                    ],
                )
                .unwrap_err();
            assert!(format!("{err:#}").contains("bits"), "unhelpful: {err:#}");
            // short label vector
            let err = exec
                .execute(
                    &meta,
                    &[
                        HostTensor::F32(params.clone()),
                        HostTensor::F32(x.clone()),
                        HostTensor::I32(vec![0]),
                        HostTensor::F32(vec![1.0]),
                        HostTensor::F32(vec![4.0]),
                    ],
                )
                .unwrap_err();
            assert!(format!("{err:#}").contains("labels"), "unhelpful: {err:#}");
        }
    }

    #[test]
    fn written_artifacts_load_and_execute() {
        let dir = std::env::temp_dir().join(format!("sq_native_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let spec = tiny_spec();
        write_artifacts(&dir, &spec).unwrap();
        let reg = Registry::open(&dir).unwrap();
        assert_eq!(reg.init_params("mlp").unwrap().len(), spec.n_params());
        for variant in VARIANTS {
            for step in [StepKind::Train, StepKind::Probe] {
                let meta = reg.meta("mlp", variant, step).unwrap();
                assert!(meta.hlo_path.exists());
                assert_eq!(meta.n_params, spec.n_params());
            }
        }
        let meta = reg.meta("mlp", "qat", StepKind::Eval).unwrap().clone();
        let (x, y) = tiny_batch(&spec, 2);
        let out = NativeExecutor::default()
            .execute(
                &meta,
                &[
                    HostTensor::F32(reg.init_params("mlp").unwrap()),
                    HostTensor::F32(x),
                    HostTensor::I32(y),
                ],
            )
            .unwrap();
        assert_eq!(out.len(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_out_of_range_labels() {
        let spec = tiny_spec();
        let meta = tiny_meta("qat", StepKind::Eval);
        let (x, _) = tiny_batch(&spec, 2);
        let bad_y = vec![spec.classes as i32; spec.batch];
        let res = NativeExecutor::default().execute(
            &meta,
            &[
                HostTensor::F32(init_params(&spec)),
                HostTensor::F32(x),
                HostTensor::I32(bad_y),
            ],
        );
        assert!(res.is_err());
    }
}
