//! PJRT runtime (S7): load AOT artifacts, validate their ABI metadata,
//! compile once, execute many times from the L3 hot loop.
//!
//! Interchange is HLO *text* (see DESIGN.md §2): jax >= 0.5 emits protos
//! with 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids. Python never runs at request time — the Rust
//! binary is self-contained once `make artifacts` has populated
//! `artifacts/`.

pub mod artifact;
pub mod executor;

pub use artifact::{ArtifactMeta, Registry, StepKind, TensorSpec};
pub use executor::{Executor, HostTensor, StepOutputs};

use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::Arc;

use anyhow::Result;

/// Shared PJRT CPU client + executable cache. One per process; XLA
/// compilation of an artifact is paid once per (model, variant, step)
/// even across many experiment runs (the Table-1 sweep reuses one
/// compiled train step for all bitwidths — `bits` is a runtime input).
pub struct Runtime {
    client: xla::PjRtClient,
    cache: RefCell<HashMap<String, Arc<Executor>>>,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Self {
            client,
            cache: RefCell::new(HashMap::new()),
        })
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load-or-reuse the compiled executable for an artifact.
    pub fn executor(&self, meta: &ArtifactMeta) -> Result<Arc<Executor>> {
        let key = meta.key();
        if let Some(e) = self.cache.borrow().get(&key) {
            return Ok(e.clone());
        }
        let t0 = std::time::Instant::now();
        let exec = Arc::new(Executor::load(self, meta)?);
        eprintln!(
            "[runtime] compiled {key} in {:.1}s",
            t0.elapsed().as_secs_f64()
        );
        self.cache.borrow_mut().insert(key, exec.clone());
        Ok(exec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_boots() {
        let rt = Runtime::cpu().expect("pjrt cpu client");
        assert_eq!(rt.platform(), "cpu");
    }
}
