//! Runtime (S7): load AOT artifacts, validate their ABI metadata, and
//! execute step functions from the L3 hot loop through a pluggable
//! backend (see DESIGN.md for the trait + feature matrix).
//!
//! Two backends implement [`ExecutorBackend`]:
//!
//!  * **native** (always available) — a pure-Rust interpreter for the
//!    `mlp` artifacts' forward/backward, reusing the native quantizer
//!    stack. Keeps the whole experiment pipeline runnable on machines
//!    without an XLA toolchain.
//!  * **pjrt** (`--features pjrt`) — compiles the artifacts' HLO text on
//!    the XLA CPU client. The offline build links a vendored stub that
//!    type-checks this path but reports PJRT unavailable at boot, so
//!    [`Runtime::cpu`] silently falls back to the native interpreter.

pub mod artifact;
pub mod executor;
pub mod kernels;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use artifact::{ArtifactMeta, Registry, StepKind, TensorSpec};
pub use executor::{Executor, ExecutorBackend, HostTensor, StepOutputs};
pub use native::{ComputeMode, KernelPath, MlpSpec, NativeExecutor};

use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::Arc;

use anyhow::Result;

enum Backend {
    Native,
    #[cfg(feature = "pjrt")]
    Pjrt(pjrt::PjrtRuntime),
}

/// Backend selector + executor cache. One per process; building an
/// executor for an artifact is paid once per (model, variant, step)
/// even across many experiment runs (the Table-1 sweep reuses one
/// train step for all bitwidths — `bits` is a runtime input).
pub struct Runtime {
    backend: Backend,
    compute: ComputeMode,
    cache: RefCell<HashMap<String, Arc<Executor>>>,
}

impl Runtime {
    /// Preferred constructor: PJRT CPU client when the `pjrt` feature is
    /// enabled *and* real bindings are linked, native interpreter
    /// otherwise. Infallible in practice; the `Result` is kept so call
    /// sites are stable across backends.
    pub fn cpu() -> Result<Self> {
        #[cfg(feature = "pjrt")]
        match pjrt::PjrtRuntime::cpu() {
            Ok(rt) => {
                return Ok(Self {
                    backend: Backend::Pjrt(rt),
                    compute: ComputeMode::default(),
                    cache: RefCell::new(HashMap::new()),
                })
            }
            Err(e) => {
                eprintln!("[runtime] PJRT unavailable ({e:#}); using native interpreter");
            }
        }
        Ok(Self::native())
    }

    /// Force the native interpreter backend.
    pub fn native() -> Self {
        Self {
            backend: Backend::Native,
            compute: ComputeMode::default(),
            cache: RefCell::new(HashMap::new()),
        }
    }

    /// Select the backward arithmetic mode for native executors built
    /// after this call (`--compute {simulate,int8}`). Call before the
    /// first [`Self::executor`] — cached executors keep the mode they
    /// were built with, so flipping mid-run would split the cache's
    /// behavior by build order; the cache is cleared to keep the mode
    /// uniform. The PJRT backend ignores this (its HLO is simulate-only).
    pub fn set_compute(&mut self, compute: ComputeMode) {
        if self.compute != compute {
            self.compute = compute;
            self.cache.borrow_mut().clear();
        }
    }

    pub fn compute(&self) -> ComputeMode {
        self.compute
    }

    pub fn platform(&self) -> String {
        match &self.backend {
            Backend::Native => "native".to_string(),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(rt) => rt.platform(),
        }
    }

    /// Load-or-reuse the executor for an artifact.
    pub fn executor(&self, meta: &ArtifactMeta) -> Result<Arc<Executor>> {
        let key = meta.key();
        if let Some(e) = self.cache.borrow().get(&key) {
            return Ok(e.clone());
        }
        let backend: Box<dyn ExecutorBackend> = match &self.backend {
            Backend::Native => Box::new(NativeExecutor::default().with_compute(self.compute)),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(rt) => {
                let t0 = std::time::Instant::now();
                let b = Box::new(rt.load(meta)?);
                eprintln!(
                    "[runtime] compiled {key} in {:.1}s",
                    t0.elapsed().as_secs_f64()
                );
                b
            }
        };
        let exec = Arc::new(Executor::new(meta.clone(), backend));
        self.cache.borrow_mut().insert(key, exec.clone());
        Ok(exec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runtime_boots_and_reports_platform() {
        let rt = Runtime::cpu().expect("runtime boots");
        assert!(!rt.platform().is_empty());
    }

    #[test]
    fn native_runtime_builds_cached_executors() {
        let rt = Runtime::native();
        assert_eq!(rt.platform(), "native");
        let dir = std::env::temp_dir().join(format!("sq_rt_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        native::write_artifacts(&dir, &MlpSpec::default()).unwrap();
        let reg = Registry::open(&dir).unwrap();
        let meta = reg.meta("mlp", "ptq", StepKind::Train).unwrap();
        let a = rt.executor(meta).unwrap();
        let b = rt.executor(meta).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "executor cache must dedupe");
        assert_eq!(a.backend_name(), "native");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn set_compute_clears_cache_and_sticks() {
        let mut rt = Runtime::native();
        assert_eq!(rt.compute(), ComputeMode::Simulate);
        let dir = std::env::temp_dir().join(format!("sq_rt_cm_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        native::write_artifacts(&dir, &MlpSpec::default()).unwrap();
        let reg = Registry::open(&dir).unwrap();
        let meta = reg.meta("mlp", "ptq", StepKind::Train).unwrap().clone();
        let a = rt.executor(&meta).unwrap();
        rt.set_compute(ComputeMode::Int8);
        assert_eq!(rt.compute(), ComputeMode::Int8);
        let b = rt.executor(&meta).unwrap();
        assert!(
            !Arc::ptr_eq(&a, &b),
            "mode switch must invalidate cached executors"
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}
