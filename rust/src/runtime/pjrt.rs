//! PJRT/XLA backend (`--features pjrt`): HLO text -> PJRT compile ->
//! execute on the XLA CPU client.
//!
//! Interchange is HLO *text* (see DESIGN.md): jax >= 0.5 emits protos
//! with 64-bit instruction ids that xla_extension 0.5.1 rejects; the
//! text parser reassigns ids. The offline build links the vendored
//! `xla` stub, which compiles this module but reports PJRT unavailable
//! at client-boot time so [`crate::runtime::Runtime`] falls back to the
//! native interpreter.

use anyhow::{anyhow, bail, Context, Result};

use super::artifact::ArtifactMeta;
use super::executor::{ExecutorBackend, HostTensor, StepOutputs};

/// Shared PJRT CPU client; XLA compilation of an artifact is paid once
/// per (model, variant, step) via the [`crate::runtime::Runtime`] cache.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

impl PjrtRuntime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Self { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load the artifact's HLO text and compile it on the PJRT client.
    pub fn load(&self, meta: &ArtifactMeta) -> Result<PjrtBackend> {
        let _sp = crate::obs::span("pjrt/compile");
        let proto = xla::HloModuleProto::from_text_file(&meta.hlo_path)
            .with_context(|| format!("loading {}", meta.hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", meta.key()))?;
        Ok(PjrtBackend { exe })
    }
}

/// One compiled executable.
pub struct PjrtBackend {
    exe: xla::PjRtLoadedExecutable,
}

impl ExecutorBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn execute(&self, meta: &ArtifactMeta, inputs: &[HostTensor]) -> Result<StepOutputs> {
        let _sp = crate::obs::span("pjrt/execute");
        let mut lits = Vec::with_capacity(inputs.len());
        for (t, spec) in inputs.iter().zip(&meta.inputs) {
            lits.push(to_literal(t, &spec.shape)?);
        }
        let result = self.exe.execute::<xla::Literal>(&lits)?;
        let tuple = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| anyhow!("empty execution result"))?
            .to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        parts.iter().map(from_literal).collect()
    }
}

fn to_literal(t: &HostTensor, shape: &[usize]) -> Result<xla::Literal> {
    if shape.is_empty() {
        // rank-0 scalar
        return Ok(match t {
            HostTensor::F32(v) => xla::Literal::scalar(v[0]),
            HostTensor::I32(v) => xla::Literal::scalar(v[0]),
        });
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    let lit = match t {
        HostTensor::F32(v) => xla::Literal::vec1(v),
        HostTensor::I32(v) => xla::Literal::vec1(v),
    };
    if shape.len() == 1 && lit.element_count() == shape[0] {
        return Ok(lit);
    }
    Ok(lit.reshape(&dims)?)
}

fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
    use xla::ElementType;
    match lit.ty()? {
        ElementType::F32 => Ok(HostTensor::F32(lit.to_vec::<f32>()?)),
        ElementType::S32 => Ok(HostTensor::I32(lit.to_vec::<i32>()?)),
        other => bail!("unsupported output element type {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let t = HostTensor::F32(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let lit = to_literal(&t, &[2, 3]).unwrap();
        assert_eq!(lit.element_count(), 6);
        let back = from_literal(&lit).unwrap();
        assert_eq!(back.as_f32().unwrap(), &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn literal_scalar_shape() {
        let t = HostTensor::F32(vec![7.5]);
        let lit = to_literal(&t, &[]).unwrap();
        assert_eq!(lit.element_count(), 1);
    }
}
