//! L3 coordinator (S8/S12): training loop, LR schedules, checkpointing,
//! and the data-parallel quantized-all-reduce simulation.
//!
//! The paper's contribution lives at L1/L2 (the quantizers and the FQT
//! backward); per DESIGN.md the coordinator is the training *framework*
//! around it — it owns process lifecycle, the step loop, metrics, and
//! every experiment driver, and it is the only code on the request path.

pub mod checkpoint;
pub mod data_parallel;
pub mod lr;
pub mod trainer;

pub use checkpoint::Checkpoint;
pub use data_parallel::{DataParallel, ReduceMode};
pub use lr::Schedule;
pub use trainer::{make_dataset, train_data_parallel, TrainReport, Trainer};
