//! Training coordinator (S8): the L3 driver around the fused train-step
//! artifact — LR schedule, data feed, eval, metrics, checkpointing.
//!
//! Hot loop: one executor dispatch per step (PJRT or the native backend,
//! see `runtime`); the optimizer (momentum SGD, paper Appendix E) is
//! fused *inside* the artifact, so the coordinator only shuttles the
//! flat state vectors and scalars.

use std::path::PathBuf;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use super::checkpoint::Checkpoint;
use super::data_parallel::{DataParallel, ReduceMode};
use super::lr::Schedule;
use crate::config::TrainConfig;
use crate::quant::GradQuantizer;
use crate::data::markov::{Markov, MarkovConfig};
use crate::data::synthimg::{SynthImg, SynthImgConfig};
use crate::data::Dataset;
use crate::metrics::{CsvWriter, JsonlWriter};
use crate::obs;
use crate::runtime::{Executor, HostTensor, Registry, Runtime, StepKind};
use crate::util::json::{obj, Json};

/// Final outcome of one training run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub run_name: String,
    pub steps: u64,
    pub final_train_loss: f64,
    pub final_eval_loss: f64,
    pub final_eval_acc: f64,
    pub diverged: bool,
    /// The step at which the divergence guard tripped, when it did.
    pub diverged_at_step: Option<u64>,
    pub wall_seconds: f64,
    pub steps_per_second: f64,
    pub curve: Vec<(u64, f64)>,
    pub params: Vec<f32>,
}

/// NaN/inf would serialize as invalid JSON through `Json::Num`.
fn finite_or_null(v: f64) -> Json {
    if v.is_finite() {
        Json::from(v)
    } else {
        Json::Null
    }
}

/// Build the dataset matching a model's ABI from the config.
pub fn make_dataset(cfg: &TrainConfig, meta_input: &[usize], kind_hint: &str) -> Box<dyn Dataset> {
    if kind_hint == "markov" || cfg.data.kind == "markov" {
        Box::new(Markov::new(MarkovConfig {
            vocab: 256,
            seq: meta_input[1],
            batch: meta_input[0],
            seed: cfg.data.seed,
            ..Default::default()
        }))
    } else {
        Box::new(SynthImg::new(SynthImgConfig {
            classes: 10,
            dims: meta_input[1..].to_vec(),
            batch: meta_input[0],
            noise: cfg.data.noise,
            hard_frac: cfg.data.hard_frac,
            seed: cfg.data.seed,
        }))
    }
}

/// Drive the data-parallel engine (dense or threaded ring) for a full
/// run. The per-worker probe artifact replaces the fused train step —
/// the update runs in Rust so the gradients can pass through the
/// all-reduce quantizer — while eval still uses the fused eval
/// artifact. The run dir receives the same artifact set as
/// [`Trainer::train`] (log.jsonl, curve.csv, metrics.prom, trace.json,
/// final checkpoint), reconstructed post hoc because the threaded pool
/// owns the step loop.
pub fn train_data_parallel(rt: &Runtime, reg: &Registry, cfg: TrainConfig) -> Result<TrainReport> {
    cfg.validate()?;
    let probe_meta = reg.meta(&cfg.model, &cfg.variant, StepKind::Probe)?;
    let eval_meta = reg.meta(&cfg.model, "qat", StepKind::Eval)?;
    let probe = rt.executor(probe_meta)?;
    let eval_exec = rt.executor(eval_meta)?;
    let mut params = reg.init_params(&cfg.model)?;
    let mut velocity = vec![0.0f32; params.len()];
    let kind_hint = if cfg.model == "transformer" {
        "markov"
    } else {
        "synthimg"
    };
    let dataset = make_dataset(&cfg, &probe_meta.input_shape, kind_hint);
    let quantizer = GradQuantizer::from_name(&cfg.allreduce_quant)
        .ok_or_else(|| anyhow!("unknown allreduce_quant {:?}", cfg.allreduce_quant))?;
    let mode = ReduceMode::from_name(&cfg.dp_mode)
        .ok_or_else(|| anyhow!("unknown dp_mode {:?}", cfg.dp_mode))?;
    let dp = DataParallel {
        probe: &probe,
        workers: cfg.workers,
        allreduce_bits: cfg.allreduce_bits,
        quantizer,
        momentum: 0.9, // paper Appendix E, as in the fused artifacts
        threads: cfg.dp_threads,
        mode,
    };
    let schedule = Schedule::from_name(&cfg.schedule).context("unknown schedule")?;
    let warmup = (cfg.steps as f64 * cfg.warmup_frac) as u64;
    let out_dir = PathBuf::from(&cfg.out_dir).join(cfg.run_name());
    std::fs::create_dir_all(&out_dir)?;

    let t0 = Instant::now();
    let hist = dp.train_with_state(
        dataset.as_ref(),
        &mut params,
        &mut velocity,
        cfg.steps,
        cfg.lr,
        schedule,
        warmup,
        cfg.bits,
        cfg.seed,
    )?;
    let wall = t0.elapsed().as_secs_f64();

    let mut csv = CsvWriter::create(
        out_dir.join("curve.csv"),
        &["step", "lr", "train_loss", "grad_norm_sq"],
    )?;
    let mut curve = Vec::with_capacity(hist.len());
    let mut diverged_at_step = None;
    for (step, s) in hist.iter().enumerate() {
        let lr = schedule.lr(cfg.lr, step as u64, cfg.steps, warmup);
        csv.rowf(&[step as f64, lr, s.loss, s.grad_norm_sq])?;
        if diverged_at_step.is_none() && (!s.loss.is_finite() || s.loss > 1e4) {
            diverged_at_step = Some(step as u64);
        }
        curve.push((step as u64, s.loss));
    }
    let diverged = diverged_at_step.is_some();
    let (el, ea) = if diverged {
        (f64::NAN, 0.0)
    } else {
        let mut loss = 0.0;
        let mut acc = 0.0;
        for i in 0..cfg.eval_batches {
            let b = dataset.eval_batch(i);
            let out = eval_exec.run(&[HostTensor::F32(params.clone()), b.x, b.y])?;
            loss += f64::from(out[0].as_f32()?[0]);
            acc += f64::from(out[1].as_f32()?[0]);
        }
        let n = cfg.eval_batches.max(1) as f64;
        (loss / n, acc / n)
    };
    let final_train_loss = hist.last().map_or(f64::NAN, |s| s.loss);
    let mut jsonl = JsonlWriter::create(out_dir.join("log.jsonl"))?;
    jsonl.write(&obj([
        ("mode", Json::from(mode.name())),
        ("workers", Json::from(cfg.workers)),
        ("dp_threads", Json::from(cfg.dp_threads)),
        ("allreduce_bits", Json::from(f64::from(cfg.allreduce_bits))),
        ("steps", Json::from(hist.len())),
        ("final_train_loss", finite_or_null(final_train_loss)),
        ("eval_loss", finite_or_null(el)),
        ("eval_acc", Json::from(ea)),
    ]))?;
    if obs::enabled() {
        let m = obs::metrics();
        std::fs::write(out_dir.join("metrics.prom"), m.render_prometheus())?;
        obs::span::write_chrome_trace(&out_dir.join("trace.json"))?;
    }
    Checkpoint {
        step: hist.len() as u64,
        params: params.clone(),
        momentum: velocity,
    }
    .save(&out_dir)?;
    Ok(TrainReport {
        run_name: cfg.run_name(),
        steps: hist.len() as u64,
        final_train_loss,
        final_eval_loss: el,
        final_eval_acc: ea,
        diverged,
        diverged_at_step,
        wall_seconds: wall,
        steps_per_second: hist.len() as f64 / wall.max(1e-9),
        curve,
        params,
    })
}

pub struct Trainer {
    pub cfg: TrainConfig,
    pub train_exec: std::sync::Arc<Executor>,
    pub eval_exec: std::sync::Arc<Executor>,
    pub dataset: Box<dyn Dataset>,
    pub params: Vec<f32>,
    pub momentum: Vec<f32>,
    out_dir: PathBuf,
}

impl Trainer {
    pub fn new(rt: &Runtime, reg: &Registry, cfg: TrainConfig) -> Result<Self> {
        cfg.validate()?;
        let train_meta = reg.meta(&cfg.model, &cfg.variant, StepKind::Train)?;
        let eval_meta = reg.meta(&cfg.model, "qat", StepKind::Eval)?;
        let train_exec = rt.executor(train_meta)?;
        let eval_exec = rt.executor(eval_meta)?;
        let params = reg.init_params(&cfg.model)?;
        if params.len() != train_meta.n_params {
            bail!(
                "init params {} != artifact n_params {}",
                params.len(),
                train_meta.n_params
            );
        }
        let momentum = vec![0.0; params.len()];
        let kind_hint = if cfg.model == "transformer" {
            "markov"
        } else {
            "synthimg"
        };
        let dataset = make_dataset(&cfg, &train_meta.input_shape, kind_hint);
        let out_dir = PathBuf::from(&cfg.out_dir).join(cfg.run_name());
        Ok(Self {
            cfg,
            train_exec,
            eval_exec,
            dataset,
            params,
            momentum,
            out_dir,
        })
    }

    fn step_once(&mut self, step: u64, lr: f64) -> Result<(f64, f64)> {
        let batch = {
            let _sp = obs::span("train/data");
            self.dataset.batch(step)
        };
        // seed folds the run seed with the step so every step draws fresh
        // SR noise but the whole run replays exactly.
        let seed = (self.cfg.seed.wrapping_mul(1_000_003) + step) % 16_777_213;
        let inputs = [
            HostTensor::F32(std::mem::take(&mut self.params)),
            HostTensor::F32(std::mem::take(&mut self.momentum)),
            batch.x,
            batch.y,
            HostTensor::F32(vec![seed as f32]),
            HostTensor::F32(vec![lr as f32]),
            HostTensor::F32(vec![self.cfg.bits]),
        ];
        let mut out = {
            let _sp = obs::span("train/dispatch");
            self.train_exec.run(&inputs)?
        };
        // outputs: (params', momentum', loss, acc)
        let acc = out.pop().expect("acc").into_f32()?[0] as f64;
        let loss = out.pop().expect("loss").into_f32()?[0] as f64;
        self.momentum = out.pop().expect("momentum").into_f32()?;
        self.params = out.pop().expect("params").into_f32()?;
        Ok((loss, acc))
    }

    /// Single-step driver at the configured base LR — used by the bench
    /// harness to measure hot-loop latency without schedule/logging.
    pub fn train_step_bench(&mut self, step: u64) -> Result<(f64, f64)> {
        self.step_once(step, self.cfg.lr)
    }

    /// Evaluate on `n` held-out batches (loss, accuracy).
    pub fn evaluate(&self, n: u64) -> Result<(f64, f64)> {
        let mut loss = 0.0;
        let mut acc = 0.0;
        for i in 0..n {
            let b = self.dataset.eval_batch(i);
            let inputs = [HostTensor::F32(self.params.clone()), b.x, b.y];
            let out = self.eval_exec.run(&inputs)?;
            loss += out[0].as_f32()?[0] as f64;
            acc += out[1].as_f32()?[0] as f64;
        }
        Ok((loss / n as f64, acc / n as f64))
    }

    /// Run the configured number of steps, logging curves + checkpoints.
    /// With obs enabled the run directory additionally receives
    /// `metrics.prom` (Prometheus text), `metrics.jsonl` (registry
    /// snapshots at eval points), and `trace.json` (Chrome trace).
    pub fn train(&mut self) -> Result<TrainReport> {
        let schedule = Schedule::from_name(&self.cfg.schedule)
            .context("unknown schedule")?;
        let warmup = (self.cfg.steps as f64 * self.cfg.warmup_frac) as u64;
        let mut jsonl = JsonlWriter::create(self.out_dir.join("log.jsonl"))?;
        let mut metrics_jsonl = JsonlWriter::create(self.out_dir.join("metrics.jsonl"))?;
        let mut csv = CsvWriter::create(
            self.out_dir.join("curve.csv"),
            &["step", "lr", "train_loss", "train_acc"],
        )?;
        let m = obs::metrics();
        let steps_total = m.counter("train_steps_total", "training steps completed");
        let diverged_total =
            m.counter("train_diverged_total", "runs that hit the divergence guard");
        let step_seconds = m.histogram(
            "train_step_seconds",
            "wall time of one fused train step",
            &obs::registry::TIME_BUCKETS,
        );
        let mut curve = Vec::new();
        let mut diverged_at_step = None;
        let mut last_loss = f64::NAN;
        // quantizer-telemetry baseline: report per-eval-window deltas so
        // clip rates reflect this run, not process-lifetime totals.
        let mut last_q = obs::quant::totals_for(&self.cfg.variant);
        let t0 = Instant::now();
        for step in 0..self.cfg.steps {
            let _step_span = obs::span("train/step");
            let lr = schedule.lr(self.cfg.lr, step, self.cfg.steps, warmup);
            let ts = Instant::now();
            let (loss, acc) = self.step_once(step, lr)?;
            step_seconds.observe(ts.elapsed().as_secs_f64());
            steps_total.inc();
            last_loss = loss;
            if !loss.is_finite() || loss > 1e4 {
                diverged_at_step = Some(step);
                diverged_total.inc();
                obs::event(
                    "train_diverged",
                    &[
                        ("run", self.cfg.run_name()),
                        ("step", step.to_string()),
                        ("loss", format!("{loss}")),
                    ],
                );
                jsonl.write(&obj([
                    ("step", Json::from(step as usize)),
                    ("event", Json::from("diverged")),
                    ("diverged_at_step", Json::from(step as usize)),
                    // loss may be NaN/inf here — keep the repr as a string
                    ("train_loss_repr", Json::from(format!("{loss}"))),
                ]))?;
                break;
            }
            curve.push((step, loss));
            {
                let _sp = obs::span("train/metrics");
                csv.rowf(&[step as f64, lr, loss, acc])?;
            }
            if step % self.cfg.eval_every == 0 || step + 1 == self.cfg.steps {
                let _sp = obs::span("train/eval");
                let (el, ea) = self.evaluate(self.cfg.eval_batches)?;
                let q = obs::quant::totals_for(&self.cfg.variant);
                let dq = q.since(&last_q);
                last_q = q;
                jsonl.write(&obj([
                    ("step", Json::from(step as usize)),
                    ("lr", Json::from(lr)),
                    ("train_loss", Json::from(loss)),
                    ("train_acc", Json::from(acc)),
                    ("eval_loss", Json::from(el)),
                    ("eval_acc", Json::from(ea)),
                    ("quant_clip_rate", Json::from(dq.clip_rate())),
                    ("quant_zero_rate", Json::from(dq.zero_rate())),
                    ("quant_grad_var", finite_or_null(q.var_last)),
                    ("quant_grad_var_mean", finite_or_null(q.var_mean)),
                ]))?;
                if obs::enabled() {
                    metrics_jsonl.write(&m.snapshot_json())?;
                }
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let (el, ea) = if diverged_at_step.is_some() {
            (f64::NAN, 0.0)
        } else {
            self.evaluate(self.cfg.eval_batches)?
        };
        if obs::enabled() {
            std::fs::write(self.out_dir.join("metrics.prom"), m.render_prometheus())?;
            metrics_jsonl.write(&m.snapshot_json())?;
            obs::span::write_chrome_trace(&self.out_dir.join("trace.json"))?;
        }
        let done = curve.len() as u64;
        Ok(TrainReport {
            run_name: self.cfg.run_name(),
            steps: done,
            final_train_loss: last_loss,
            final_eval_loss: el,
            final_eval_acc: ea,
            diverged: diverged_at_step.is_some(),
            diverged_at_step,
            wall_seconds: wall,
            steps_per_second: done as f64 / wall.max(1e-9),
            curve,
            params: self.params.clone(),
        })
    }
}

#[cfg(test)]
mod tests {
    // Trainer integration tests live in rust/tests/integration.rs (they
    // need compiled artifacts); unit coverage here targets the pure bits.
    use super::*;

    #[test]
    fn make_dataset_dispatch() {
        let cfg = TrainConfig::default();
        let d = make_dataset(&cfg, &[8, 16, 16, 3], "synthimg");
        assert_eq!(d.batch_size(), 8);
        let d = make_dataset(&cfg, &[4, 32], "markov");
        assert_eq!(d.batch_size(), 4);
        let b = d.batch(0);
        assert_eq!(b.x.len(), 4 * 32);
    }
}
