//! Learning-rate schedules (paper Appendix E: linear warmup + cosine).

/// Schedule kinds supported by the config system.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Schedule {
    /// Linear warmup then cosine decay to zero (the paper's setting).
    Cosine,
    /// Linear warmup then constant.
    Constant,
    /// Warmup then /10 at 50% and 75% of training (classic ResNet step).
    Step,
}

impl Schedule {
    pub fn from_name(s: &str) -> Option<Self> {
        match s {
            "cosine" => Some(Schedule::Cosine),
            "constant" => Some(Schedule::Constant),
            "step" => Some(Schedule::Step),
            _ => None,
        }
    }

    /// LR at `step` of `total` with `base` peak LR and `warmup` steps.
    pub fn lr(self, base: f64, step: u64, total: u64, warmup: u64) -> f64 {
        let total = total.max(1);
        if warmup > 0 && step < warmup {
            return base * (step + 1) as f64 / warmup as f64;
        }
        match self {
            Schedule::Constant => base,
            Schedule::Cosine => {
                let t = (step - warmup) as f64 / (total.saturating_sub(warmup)).max(1) as f64;
                let t = t.clamp(0.0, 1.0);
                base * 0.5 * (1.0 + (std::f64::consts::PI * t).cos())
            }
            Schedule::Step => {
                let frac = step as f64 / total as f64;
                if frac < 0.5 {
                    base
                } else if frac < 0.75 {
                    base * 0.1
                } else {
                    base * 0.01
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_ramps_linearly() {
        let s = Schedule::Cosine;
        let lr0 = s.lr(1.0, 0, 100, 10);
        let lr5 = s.lr(1.0, 4, 100, 10);
        let lr9 = s.lr(1.0, 9, 100, 10);
        assert!((lr0 - 0.1).abs() < 1e-12);
        assert!((lr5 - 0.5).abs() < 1e-12);
        assert!((lr9 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn cosine_decays_to_zero() {
        let s = Schedule::Cosine;
        assert!((s.lr(1.0, 10, 100, 10) - 1.0).abs() < 1e-9);
        let mid = s.lr(1.0, 55, 100, 10);
        assert!((mid - 0.5).abs() < 0.01, "{mid}");
        let end = s.lr(1.0, 99, 100, 10);
        assert!(end < 0.01, "{end}");
    }

    #[test]
    fn cosine_monotone_after_warmup() {
        let s = Schedule::Cosine;
        let mut prev = f64::INFINITY;
        for step in 10..100 {
            let lr = s.lr(0.4, step, 100, 10);
            assert!(lr <= prev + 1e-12);
            prev = lr;
        }
    }

    #[test]
    fn step_schedule_drops() {
        let s = Schedule::Step;
        assert_eq!(s.lr(1.0, 10, 100, 0), 1.0);
        assert_eq!(s.lr(1.0, 60, 100, 0), 0.1);
        assert!((s.lr(1.0, 80, 100, 0) - 0.01).abs() < 1e-12);
    }

    #[test]
    fn zero_warmup_ok() {
        assert_eq!(Schedule::Constant.lr(0.3, 0, 10, 0), 0.3);
    }

    #[test]
    fn from_name_total() {
        assert_eq!(Schedule::from_name("cosine"), Some(Schedule::Cosine));
        assert_eq!(Schedule::from_name("nope"), None);
    }
}
