//! Checkpointing: flat parameter/momentum state as f32-LE blobs plus a
//! JSON manifest (step, config echo) — the same wire format aot.py uses
//! for initial parameters, so checkpoints and inits are interchangeable.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::{obj, Json};

#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub step: u64,
    pub params: Vec<f32>,
    pub momentum: Vec<f32>,
}

fn write_f32le(path: &Path, data: &[f32]) -> Result<()> {
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for &v in data {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    std::fs::write(path, bytes).with_context(|| format!("writing {}", path.display()))
}

fn read_f32le(path: &Path) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() % 4 != 0 {
        bail!("{} length not a multiple of 4", path.display());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect())
}

impl Checkpoint {
    /// Write `<dir>/ckpt_<step>.{params,momentum}.bin` + manifest.
    pub fn save(&self, dir: &Path) -> Result<PathBuf> {
        let _sp = crate::obs::span("ckpt/save");
        std::fs::create_dir_all(dir)?;
        let stem = dir.join(format!("ckpt_{:08}", self.step));
        write_f32le(&stem.with_extension("params.bin"), &self.params)?;
        write_f32le(&stem.with_extension("momentum.bin"), &self.momentum)?;
        let meta = obj([
            ("step", Json::from(self.step as usize)),
            ("n_params", Json::from(self.params.len())),
        ]);
        let meta_path = stem.with_extension("json");
        std::fs::write(&meta_path, meta.to_string_pretty())?;
        Ok(meta_path)
    }

    pub fn load(meta_path: &Path) -> Result<Self> {
        let _sp = crate::obs::span("ckpt/load");
        let text = std::fs::read_to_string(meta_path)?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let step = j
            .get("step")
            .and_then(Json::as_usize)
            .context("checkpoint missing step")? as u64;
        let stem = meta_path.with_extension("");
        let params = read_f32le(&stem.with_extension("params.bin"))?;
        let momentum = read_f32le(&stem.with_extension("momentum.bin"))?;
        if params.len() != momentum.len() {
            bail!("params/momentum length mismatch");
        }
        Ok(Self {
            step,
            params,
            momentum,
        })
    }

    /// Most recent checkpoint in a run directory, if any.
    pub fn latest(dir: &Path) -> Result<Option<Self>> {
        if !dir.is_dir() {
            return Ok(None);
        }
        let mut metas: Vec<PathBuf> = std::fs::read_dir(dir)?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| {
                p.file_name()
                    .and_then(|s| s.to_str())
                    .is_some_and(|s| s.starts_with("ckpt_") && s.ends_with(".json"))
            })
            .collect();
        metas.sort();
        match metas.last() {
            Some(p) => Ok(Some(Self::load(p)?)),
            None => Ok(None),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join(format!("sq_ckpt_{}", std::process::id()));
        let ck = Checkpoint {
            step: 42,
            params: vec![1.5, -2.25, 0.0],
            momentum: vec![0.1, 0.2, 0.3],
        };
        let meta = ck.save(&dir).unwrap();
        let back = Checkpoint::load(&meta).unwrap();
        assert_eq!(back, ck);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn latest_picks_highest_step() {
        let dir = std::env::temp_dir().join(format!("sq_ckpt2_{}", std::process::id()));
        for step in [10u64, 200, 30] {
            Checkpoint {
                step,
                params: vec![step as f32],
                momentum: vec![0.0],
            }
            .save(&dir)
            .unwrap();
        }
        let latest = Checkpoint::latest(&dir).unwrap().unwrap();
        assert_eq!(latest.step, 200);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn latest_on_missing_dir_is_none() {
        assert!(Checkpoint::latest(Path::new("/nonexistent/xyz"))
            .unwrap()
            .is_none());
    }
}
