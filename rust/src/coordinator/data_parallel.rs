//! Data-parallel FQT engine (S12) — the paper's quantizers applied to
//! *gradient communication*, the natural systems extension of §4 (the
//! "future directions" the paper sketches for distributed training).
//!
//! W logical workers each evaluate the probe artifact on their own shard
//! of the global batch (worker w, step t sees batch t*W + w). Two reduce
//! modes combine their flat gradients:
//!
//! - **Dense** (the original simulation): the (W, P) gradient matrix is
//!   quantized whole — each worker's gradient is one "sample" row — and
//!   averaged on one thread.
//! - **Ring**: parameters are split into W contiguous segments and each
//!   worker quantizes only its *outgoing* (worker, segment) payload,
//!   exactly the traffic a ring all-reduce would put on the wire. The
//!   reduce-scatter phase averages each segment over workers in
//!   canonical order (w = 0..W with a fused multiply by 1/W), and the
//!   all-gather phase publishes the reduced segments back into the
//!   parameter vector.
//!
//! Ring mode runs either serially or on a persistent scoped thread pool
//! (`threads` > 1). The determinism contract: SR noise for payload
//! (step, worker, segment) is drawn from [`segment_seed`], never from a
//! shared stream, and both reduce order and update order are fixed by
//! worker/segment index — so the final parameters are **bitwise
//! identical for any thread count**, and at `allreduce_bits = 0` the
//! ring reproduces the dense fp32 average exactly (same adds, same
//! order, same fused 1/W multiply).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Barrier, Mutex, RwLock};

use anyhow::Result;

use super::lr::Schedule;
use crate::data::Dataset;
use crate::obs;
use crate::quant::{segment, GradQuantizer, Mat};
use crate::runtime::{Executor, HostTensor};
use crate::util::rng::{Pcg32, SplitMix64};

/// Per-(step, worker) SR seed, mixed through SplitMix64 so every pair
/// maps to a distinct, decorrelated u32. The seed crosses the ABI as a
/// raw bit pattern (`f32::from_bits`) — the artifact's seed lane is a
/// bit carrier, not a numeric value — because the seed formerly crossed
/// as an f32 *value*, and `(step * 1009 + w) as f32` collapses to the
/// same float for all workers once the product exceeds 2^24, giving
/// every worker identical SR noise at large step counts.
pub fn worker_seed(step: u64, worker: usize) -> u32 {
    let folded = step.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ worker as u64;
    (SplitMix64::new(folded).next_u64() >> 32) as u32
}

/// Per-(step, worker, segment) SR seed for ring all-reduce payload
/// quantization. Each coordinate is folded through its own SplitMix64
/// finalizer before the next is mixed in, keeping the full 64-bit width
/// end to end: distinct triples map to distinct seeds (birthday-safe for
/// any realistic grid, tested in `proptests.rs`), and payload noise is
/// decorrelated from the model-gradient noise keyed by [`worker_seed`].
pub fn segment_seed(step: u64, worker: usize, segment: usize) -> u64 {
    let a = SplitMix64::new(step).next_u64()
        ^ (worker as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    let b = SplitMix64::new(a).next_u64()
        ^ (segment as u64).wrapping_mul(0xC2B2_AE3D_27D4_EB4F);
    SplitMix64::new(b).next_u64()
}

/// Pcg32 stream for ring payload SR noise (decorrelated from the dense
/// all-reduce stream 404 and the model-gradient stream 1013).
const RING_STREAM: u64 = 1117;

/// Row length used to reshape a flat ring segment for the quantizers
/// (`quant::segment`): PSQ gets per-chunk scales, BHQ a block structure
/// to mix. Part of the determinism contract — changing it changes
/// payload bits.
pub const RING_CHUNK: usize = 256;

/// Contiguous parameter ranges of the W ring segments: segment s covers
/// `[s*p/w, (s+1)*p/w)`, sizes differing by at most one element.
pub fn seg_bounds(p: usize, w: usize) -> Vec<(usize, usize)> {
    (0..w).map(|s| (s * p / w, (s + 1) * p / w)).collect()
}

/// How worker gradients are combined.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ReduceMode {
    /// Quantize the whole (W, P) gradient matrix, then average rows.
    #[default]
    Dense,
    /// Segmented quantized ring all-reduce (reduce-scatter + all-gather).
    Ring,
}

impl ReduceMode {
    pub fn name(self) -> &'static str {
        match self {
            ReduceMode::Dense => "dense",
            ReduceMode::Ring => "ring",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        match s {
            "dense" => Some(ReduceMode::Dense),
            "ring" => Some(ReduceMode::Ring),
            _ => None,
        }
    }
}

pub struct DataParallel<'a> {
    pub probe: &'a Executor,
    pub workers: usize,
    /// 0.0 = fp32 all-reduce; otherwise quantize worker gradients to this
    /// bitwidth before averaging.
    pub allreduce_bits: f32,
    pub quantizer: GradQuantizer,
    pub momentum: f64,
    /// Pool width for ring mode (1 = run the ring schedule serially).
    /// Never changes results — only where the work executes.
    pub threads: usize,
    pub mode: ReduceMode,
}

#[derive(Clone, Debug)]
pub struct DpStep {
    pub loss: f64,
    pub grad_norm_sq: f64,
}

/// One (worker, segment) outgoing payload: the raw fp32 slice at
/// `bits <= 0` or a single-worker ring, otherwise quantize-dequantized
/// with SR noise keyed by the (step, worker, segment) triple.
fn ring_payload(
    q: GradQuantizer,
    seg: &[f32],
    bits: f32,
    workers: usize,
    key: (u64, usize, usize),
    chunk: usize,
) -> Vec<f32> {
    if bits <= 0.0 || workers <= 1 {
        return seg.to_vec();
    }
    let (step, w, s) = key;
    let mut rng = Pcg32::new(segment_seed(step, w, s), RING_STREAM);
    let (deq, st) = segment::quantize_slice(q, seg, bits, chunk, &mut rng);
    if obs::enabled() {
        let m = obs::metrics();
        m.counter("ring_segments_total", "ring all-reduce payloads quantized")
            .inc();
        m.counter(
            "ring_seg_clipped_total",
            "clipped codes across ring segment payloads",
        )
        .add(st.clipped);
        if let Some(v) = st.sr_variance {
            m.gauge(
                "ring_seg_sr_variance",
                "exact SR variance of the last sampled ring segment",
            )
            .set(v);
        }
    }
    deq
}

/// Pure quantized ring all-reduce over per-worker gradient rows: each
/// worker quantizes its outgoing segments (seeded by the triple), then
/// every segment is averaged over workers in canonical order with a
/// fused multiply by 1/W. At `bits <= 0` this is bitwise the dense
/// [`mean_rows`] average. Exposed for the property tests.
pub fn ring_reduce(grads: &Mat, q: GradQuantizer, bits: f32, step: u64, chunk: usize) -> Vec<f32> {
    let (wn, p) = (grads.rows, grads.cols);
    let mut out = vec![0.0f32; p];
    if wn == 0 {
        return out;
    }
    let inv = 1.0 / wn as f32;
    for (s, &(lo, hi)) in seg_bounds(p, wn).iter().enumerate() {
        for w in 0..wn {
            let payload = ring_payload(q, &grads.row(w)[lo..hi], bits, wn, (step, w, s), chunk);
            for (o, &v) in out[lo..hi].iter_mut().zip(&payload) {
                *o += v * inv;
            }
        }
    }
    out
}

/// Momentum-SGD over the reduced gradient, in place; returns the squared
/// gradient norm. Shared verbatim by every mode so the update arithmetic
/// can never drift between dense, serial-ring, and pooled-ring paths.
fn apply_update(
    params: &mut [f32],
    velocity: &mut [f32],
    reduced: &[f32],
    momentum: f64,
    lr: f64,
) -> f64 {
    let mut gnorm = 0.0f64;
    for ((pv, vv), g) in params.iter_mut().zip(velocity.iter_mut()).zip(reduced) {
        gnorm += f64::from(*g) * f64::from(*g);
        *vv = (momentum * f64::from(*vv) + f64::from(*g)) as f32;
        *pv -= (lr * f64::from(*vv)) as f32;
    }
    gnorm
}

impl DataParallel<'_> {
    /// Pool width actually used: clamped to [1, workers].
    pub fn effective_threads(&self) -> usize {
        self.threads.clamp(1, self.workers.max(1))
    }

    /// One worker's probe dispatch: (loss, flat gradient).
    fn worker_grad(
        &self,
        dataset: &dyn Dataset,
        params: &[f32],
        step: u64,
        w: usize,
        model_bits: f32,
    ) -> Result<(f64, Vec<f32>)> {
        let batch = dataset.batch(step * self.workers as u64 + w as u64);
        let seed = f32::from_bits(worker_seed(step, w));
        let inputs = [
            HostTensor::F32(params.to_vec()),
            batch.x,
            batch.y,
            HostTensor::F32(vec![seed]),
            HostTensor::F32(vec![model_bits]),
        ];
        let out = self.probe.run(&inputs)?;
        let loss = f64::from(out[0].as_f32()?[0]);
        Ok((loss, out[1].as_f32()?.to_vec()))
    }

    fn record_step_metrics(&self, gnorm: f64) {
        if obs::enabled() {
            let m = obs::metrics();
            m.counter("dp_steps_total", "data-parallel steps").inc();
            m.gauge("dp_grad_norm_sq", "squared norm of the last reduced gradient")
                .set(gnorm);
        }
    }

    /// One synchronous data-parallel step, serial execution. Dense mode
    /// draws all-reduce SR noise from `rng`; ring mode ignores `rng`
    /// (payload noise is keyed by [`segment_seed`] so the same step is
    /// reproducible from any thread layout).
    #[allow(clippy::too_many_arguments)]
    pub fn step(
        &self,
        dataset: &dyn Dataset,
        params: &mut [f32],
        velocity: &mut [f32],
        step: u64,
        lr: f64,
        model_bits: f32,
        rng: &mut Pcg32,
    ) -> Result<DpStep> {
        match self.mode {
            ReduceMode::Dense => {
                self.step_dense(dataset, params, velocity, step, lr, model_bits, rng)
            }
            ReduceMode::Ring => self.step_ring(dataset, params, velocity, step, lr, model_bits),
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn step_dense(
        &self,
        dataset: &dyn Dataset,
        params: &mut [f32],
        velocity: &mut [f32],
        step: u64,
        lr: f64,
        model_bits: f32,
        rng: &mut Pcg32,
    ) -> Result<DpStep> {
        let _sp = obs::span("dp/step");
        let p = params.len();
        let mut grads = Mat::zeros(self.workers, p);
        let mut loss = 0.0;
        for w in 0..self.workers {
            let _wsp = obs::span("dp/worker_grad");
            let (l, g) = self.worker_grad(dataset, params, step, w, model_bits)?;
            loss += l;
            grads.row_mut(w).copy_from_slice(&g);
        }
        loss /= self.workers as f64;

        // Quantized all-reduce: each worker's gradient is a sample row.
        let reduced: Vec<f32> = if self.allreduce_bits > 0.0 && self.workers > 1 {
            let _qsp = obs::span("dp/allreduce_quant");
            let q = self.quantizer.apply(&grads, self.allreduce_bits, rng);
            mean_rows(&q)
        } else {
            mean_rows(&grads)
        };

        let gnorm = apply_update(params, velocity, &reduced, self.momentum, lr);
        self.record_step_metrics(gnorm);
        Ok(DpStep {
            loss,
            grad_norm_sq: gnorm,
        })
    }

    /// Ring schedule on the calling thread — the arithmetic reference
    /// for the pooled path (identical payloads, reduce, and update).
    fn step_ring(
        &self,
        dataset: &dyn Dataset,
        params: &mut [f32],
        velocity: &mut [f32],
        step: u64,
        lr: f64,
        model_bits: f32,
    ) -> Result<DpStep> {
        let _sp = obs::span("ring/step");
        let p = params.len();
        let mut grads = Mat::zeros(self.workers, p);
        let mut loss = 0.0;
        for w in 0..self.workers {
            let _wsp = obs::span("ring/worker_grad");
            let (l, g) = self.worker_grad(dataset, params, step, w, model_bits)?;
            loss += l;
            grads.row_mut(w).copy_from_slice(&g);
        }
        loss /= self.workers as f64;
        let reduced = {
            let _rsp = obs::span("ring/reduce_scatter");
            ring_reduce(&grads, self.quantizer, self.allreduce_bits, step, RING_CHUNK)
        };
        let gnorm = {
            let _asp = obs::span("ring/all_gather");
            apply_update(params, velocity, &reduced, self.momentum, lr)
        };
        self.record_step_metrics(gnorm);
        Ok(DpStep {
            loss,
            grad_norm_sq: gnorm,
        })
    }

    /// Convenience full run (used by the ablation experiments).
    #[allow(clippy::too_many_arguments)]
    pub fn train(
        &self,
        dataset: &dyn Dataset,
        params: &mut Vec<f32>,
        steps: u64,
        base_lr: f64,
        schedule: Schedule,
        warmup: u64,
        model_bits: f32,
        seed: u64,
    ) -> Result<Vec<DpStep>> {
        let mut velocity = vec![0.0f32; params.len()];
        self.train_with_state(
            dataset,
            params,
            &mut velocity,
            steps,
            base_lr,
            schedule,
            warmup,
            model_bits,
            seed,
        )
    }

    /// Full run with caller-owned optimizer state (so checkpoints can
    /// carry the velocity). Ring mode with `threads > 1` runs on the
    /// persistent scoped pool; everything else loops [`Self::step`].
    #[allow(clippy::too_many_arguments)]
    pub fn train_with_state(
        &self,
        dataset: &dyn Dataset,
        params: &mut Vec<f32>,
        velocity: &mut Vec<f32>,
        steps: u64,
        base_lr: f64,
        schedule: Schedule,
        warmup: u64,
        model_bits: f32,
        seed: u64,
    ) -> Result<Vec<DpStep>> {
        if self.mode == ReduceMode::Ring && self.effective_threads() > 1 {
            return self.train_ring_pool(
                dataset, params, velocity, steps, base_lr, schedule, warmup, model_bits,
            );
        }
        let mut rng = Pcg32::new(seed, 404);
        let mut out = Vec::with_capacity(steps as usize);
        for step in 0..steps {
            let lr = schedule.lr(base_lr, step, steps, warmup);
            let s = self.step(dataset, params, velocity, step, lr, model_bits, &mut rng)?;
            out.push(s);
        }
        Ok(out)
    }

    /// The threaded engine: a pool of `threads` workers living for the
    /// whole run (scoped so they can borrow the executor and dataset),
    /// coordinated per step by three barriers:
    ///
    /// 1. grad + quantize — each pool thread dispatches the probe for
    ///    its block of logical workers and quantizes their outgoing
    ///    segment payloads (seeded per triple, so placement is free);
    /// 2. reduce-scatter — each thread averages its block of segments
    ///    over workers in canonical order;
    /// 3. all-gather + update — the coordinator stitches the reduced
    ///    segments and applies the shared momentum-SGD update while the
    ///    pool waits, then releases it into the next step.
    ///
    /// Worker/segment blocks depend only on (workers, threads) and all
    /// arithmetic orders are fixed by worker/segment index, so results
    /// are bitwise identical to the serial ring schedule.
    #[allow(clippy::too_many_arguments)]
    fn train_ring_pool(
        &self,
        dataset: &dyn Dataset,
        params_out: &mut [f32],
        velocity: &mut [f32],
        steps: u64,
        base_lr: f64,
        schedule: Schedule,
        warmup: u64,
        model_bits: f32,
    ) -> Result<Vec<DpStep>> {
        struct WorkerSlot {
            loss: f64,
            /// Outgoing payloads, one per ring segment.
            payloads: Vec<Vec<f32>>,
        }
        let wn = self.workers;
        let nt = self.effective_threads();
        let p = params_out.len();
        let bounds = seg_bounds(p, wn);
        let params = RwLock::new(params_out.to_vec());
        let slots: Vec<RwLock<WorkerSlot>> = (0..wn)
            .map(|_| {
                RwLock::new(WorkerSlot {
                    loss: 0.0,
                    payloads: vec![Vec::new(); wn],
                })
            })
            .collect();
        let reduced: Vec<Mutex<Vec<f32>>> = (0..wn).map(|_| Mutex::new(Vec::new())).collect();
        let barrier = Barrier::new(nt + 1);
        let failed = AtomicBool::new(false);
        let first_err: Mutex<Option<anyhow::Error>> = Mutex::new(None);
        let mut history = Vec::with_capacity(steps as usize);
        let mut reduced_full = vec![0.0f32; p];

        std::thread::scope(|scope| {
            for ti in 0..nt {
                let (params, slots, reduced) = (&params, &slots, &reduced);
                let (barrier, failed, first_err, bounds) = (&barrier, &failed, &first_err, &bounds);
                scope.spawn(move || {
                    // Static block assignment — same partition for every
                    // pool width, so placement never shapes the bits.
                    let (wlo, whi) = (ti * wn / nt, (ti + 1) * wn / nt);
                    for step in 0..steps {
                        // A failure may be missed on this relaxed load
                        // (the phase then just does wasted work); the
                        // coordinator's post-barrier check is the
                        // authoritative one.
                        if !failed.load(Ordering::Relaxed) {
                            let snapshot = params.read().unwrap().clone();
                            for w in wlo..whi {
                                let res = {
                                    let _sp = obs::span("ring/worker_grad");
                                    self.worker_grad(dataset, &snapshot, step, w, model_bits)
                                };
                                match res {
                                    Ok((loss, grad)) => {
                                        let _qs = obs::span("ring/quantize");
                                        let mut slot = slots[w].write().unwrap();
                                        slot.loss = loss;
                                        for (s, &(lo, hi)) in bounds.iter().enumerate() {
                                            slot.payloads[s] = ring_payload(
                                                self.quantizer,
                                                &grad[lo..hi],
                                                self.allreduce_bits,
                                                wn,
                                                (step, w, s),
                                                RING_CHUNK,
                                            );
                                        }
                                    }
                                    Err(e) => {
                                        failed.store(true, Ordering::Release);
                                        first_err.lock().unwrap().get_or_insert(e);
                                    }
                                }
                            }
                        }
                        barrier.wait(); // payloads published
                        if !failed.load(Ordering::Relaxed) {
                            let _sp = obs::span("ring/reduce_scatter");
                            let inv = 1.0 / wn as f32;
                            for s in wlo..whi {
                                let (lo, hi) = bounds[s];
                                let mut acc = vec![0.0f32; hi - lo];
                                for wslot in slots.iter() {
                                    let slot = wslot.read().unwrap();
                                    for (o, &v) in acc.iter_mut().zip(&slot.payloads[s]) {
                                        *o += v * inv;
                                    }
                                }
                                *reduced[s].lock().unwrap() = acc;
                            }
                        }
                        barrier.wait(); // reduced segments published
                        barrier.wait(); // coordinator applied the update
                    }
                });
            }

            for step in 0..steps {
                barrier.wait(); // payloads ready
                barrier.wait(); // reduced segments ready
                if failed.load(Ordering::Acquire) {
                    // Keep cycling barriers so the pool drains without
                    // deadlock; the error surfaces after the scope.
                    barrier.wait();
                    continue;
                }
                let _sp = obs::span("ring/all_gather");
                for (s, &(lo, _)) in bounds.iter().enumerate() {
                    let seg = reduced[s].lock().unwrap();
                    reduced_full[lo..lo + seg.len()].copy_from_slice(&seg);
                }
                let lr = schedule.lr(base_lr, step, steps, warmup);
                let gnorm = {
                    let mut pw = params.write().unwrap();
                    apply_update(&mut pw, velocity, &reduced_full, self.momentum, lr)
                };
                let loss =
                    slots.iter().map(|s| s.read().unwrap().loss).sum::<f64>() / wn as f64;
                self.record_step_metrics(gnorm);
                history.push(DpStep {
                    loss,
                    grad_norm_sq: gnorm,
                });
                barrier.wait(); // release the pool into the next step
            }
        });

        if let Some(e) = first_err.into_inner().unwrap() {
            return Err(e);
        }
        params_out.copy_from_slice(&params.into_inner().unwrap());
        Ok(history)
    }
}

fn mean_rows(m: &Mat) -> Vec<f32> {
    let mut out = vec![0.0f32; m.cols];
    let inv = 1.0 / m.rows as f32;
    for i in 0..m.rows {
        for (o, &v) in out.iter_mut().zip(m.row(i)) {
            *o += v * inv;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_rows_averages() {
        let m = Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, 3.0, 2.0, 1.0]);
        assert_eq!(mean_rows(&m), vec![2.0, 2.0, 2.0]);
    }

    #[test]
    fn seg_bounds_partition_params() {
        for (p, w) in [(10usize, 4usize), (7, 3), (3, 5), (0, 2), (16, 1)] {
            let b = seg_bounds(p, w);
            assert_eq!(b.len(), w);
            assert_eq!(b[0].0, 0);
            assert_eq!(b[w - 1].1, p);
            for pair in b.windows(2) {
                assert_eq!(pair[0].1, pair[1].0, "gap/overlap in {b:?}");
            }
            let (min, max) = b
                .iter()
                .map(|&(lo, hi)| hi - lo)
                .fold((usize::MAX, 0), |(a, z), l| (a.min(l), z.max(l)));
            assert!(max - min <= 1, "unbalanced segments {b:?}");
        }
    }

    /// Ring reduce at bits = 0 is bitwise the dense average — the
    /// documented contract the e2e determinism test relies on.
    #[test]
    fn ring_reduce_zero_bits_is_dense_mean() {
        let mut rng = Pcg32::new(5, 2);
        let mut grads = Mat::zeros(4, 37);
        for v in &mut grads.data {
            *v = rng.normal();
        }
        let ring = ring_reduce(&grads, GradQuantizer::Psq, 0.0, 3, 8);
        let dense = mean_rows(&grads);
        assert_eq!(
            ring.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            dense.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    /// Payload bits depend only on the (step, worker, segment) triple,
    /// not on when or where the payload is produced.
    #[test]
    fn ring_reduce_is_replayable() {
        let mut rng = Pcg32::new(9, 1);
        let mut grads = Mat::zeros(3, 50);
        for v in &mut grads.data {
            *v = rng.normal();
        }
        let a = ring_reduce(&grads, GradQuantizer::Bhq, 4.0, 11, 16);
        let b = ring_reduce(&grads, GradQuantizer::Bhq, 4.0, 11, 16);
        assert_eq!(a, b);
        let c = ring_reduce(&grads, GradQuantizer::Bhq, 4.0, 12, 16);
        assert_ne!(a, c, "different step must draw different SR noise");
    }

    /// Regression: the seed formula `(step * 1009 + w) as f32` collapses
    /// adjacent workers to one float once step*1009 exceeds 2^24 (f32 has
    /// 24 mantissa bits), so all workers drew identical SR noise. The
    /// mixed seeds must stay distinct at any step count.
    #[test]
    fn worker_seeds_distinct_at_large_steps() {
        // demonstrate the seed bug first: the old formula collides
        let old = |step: u64, w: u64| (step * 1009 + w) as f32;
        assert_eq!(old(1 << 30, 0), old(1 << 30, 1));
        assert_ne!(worker_seed(1 << 30, 0), worker_seed(1 << 30, 1));

        let steps: [u64; 14] = [
            0,
            1,
            2,
            3,
            (1 << 24) - 1,
            1 << 24,
            (1 << 24) + 1,
            1 << 25,
            1 << 30,
            1 << 31,
            1 << 40,
            1 << 48,
            1 << 52,
            1 << 63,
        ];
        let mut seen = std::collections::HashSet::new();
        for &s in &steps {
            for w in 0..16usize {
                seen.insert(worker_seed(s, w));
            }
        }
        assert_eq!(seen.len(), steps.len() * 16, "seed collision in grid");
        // the f32 bit-carriers are distinct too (compare bits — some
        // patterns may be NaN, where == would lie)
        assert_ne!(
            f32::from_bits(worker_seed(1 << 30, 0)).to_bits(),
            f32::from_bits(worker_seed(1 << 30, 1)).to_bits()
        );
    }

    /// Pinned reference values: the mix must stay stable across
    /// refactors, or seeded runs stop replaying.
    #[test]
    fn worker_seed_reference_vectors() {
        assert_eq!(worker_seed(0, 0), 3_793_791_033);
        assert_eq!(worker_seed(1, 0), 1_853_398_634);
        assert_eq!(worker_seed(1 << 30, 0), 2_192_442_695);
        assert_eq!(worker_seed(1 << 30, 1), 1_923_593_825);
        assert_eq!(worker_seed(1 << 24, 3), 2_313_681_756);
        assert_eq!(worker_seed(1 << 52, 7), 726_271_972);
    }

    /// Same stability pin for the triple-keyed ring seeds: any drift in
    /// the mix silently breaks replay of seeded ring runs.
    #[test]
    fn segment_seed_reference_vectors() {
        for (step, w, s, want) in [
            (0u64, 0usize, 0usize, 2_558_736_989_570_252_433u64),
            (1, 0, 0, 12_793_040_940_332_582_595),
            (0, 1, 0, 15_728_816_339_574_814_005),
            (0, 0, 1, 17_421_853_172_286_570_939),
            (7, 3, 2, 14_050_789_424_901_263_065),
            (1 << 40, 15, 15, 9_604_362_687_286_024_047),
        ] {
            assert_eq!(segment_seed(step, w, s), want, "({step}, {w}, {s})");
        }
    }
}
