//! Data-parallel FQT simulation (S12) — the paper's quantizers applied to
//! *gradient communication*, the natural systems extension of §4 (the
//! "future directions" the paper sketches for distributed training).
//!
//! W logical workers each evaluate the probe artifact on their own shard
//! of the global batch (worker w, step t sees batch t*W + w). Their flat
//! gradients are quantized with a native Rust quantizer (PTQ/PSQ/BHQ over
//! a (workers, P) matrix — each worker's gradient is one "sample" row) and
//! all-reduced; the momentum-SGD update then runs in Rust. This exercises
//! the native quant stack on the L3 hot path and lets experiments compare
//! fp32 vs low-bit all-reduce convergence.

use anyhow::Result;

use super::lr::Schedule;
use crate::data::Dataset;
use crate::obs;
use crate::quant::{GradQuantizer, Mat};
use crate::runtime::{Executor, HostTensor};
use crate::util::rng::{Pcg32, SplitMix64};

/// Per-(step, worker) SR seed, mixed through SplitMix64 so every pair
/// maps to a distinct, decorrelated u32. The seed crosses the ABI as a
/// raw bit pattern (`f32::from_bits`) — the artifact's seed lane is a
/// bit carrier, not a numeric value — because the seed formerly crossed
/// as an f32 *value*, and `(step * 1009 + w) as f32` collapses to the
/// same float for all workers once the product exceeds 2^24, giving
/// every worker identical SR noise at large step counts.
pub fn worker_seed(step: u64, worker: usize) -> u32 {
    let folded = step.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ worker as u64;
    (SplitMix64::new(folded).next_u64() >> 32) as u32
}

pub struct DataParallel<'a> {
    pub probe: &'a Executor,
    pub workers: usize,
    /// 0.0 = fp32 all-reduce; otherwise quantize worker gradients to this
    /// bitwidth before averaging.
    pub allreduce_bits: f32,
    pub quantizer: GradQuantizer,
    pub momentum: f64,
}

#[derive(Clone, Debug)]
pub struct DpStep {
    pub loss: f64,
    pub grad_norm_sq: f64,
}

impl DataParallel<'_> {
    /// One synchronous data-parallel step: gather per-worker grads,
    /// (optionally) quantize, average, apply momentum SGD in place.
    #[allow(clippy::too_many_arguments)]
    pub fn step(
        &self,
        dataset: &dyn Dataset,
        params: &mut [f32],
        velocity: &mut [f32],
        step: u64,
        lr: f64,
        model_bits: f32,
        rng: &mut Pcg32,
    ) -> Result<DpStep> {
        let _sp = obs::span("dp/step");
        let p = params.len();
        let mut grads = Mat::zeros(self.workers, p);
        let mut loss = 0.0;
        for w in 0..self.workers {
            let _wsp = obs::span("dp/worker_grad");
            let batch = dataset.batch(step * self.workers as u64 + w as u64);
            let seed = f32::from_bits(worker_seed(step, w));
            let inputs = [
                HostTensor::F32(params.to_vec()),
                batch.x,
                batch.y,
                HostTensor::F32(vec![seed]),
                HostTensor::F32(vec![model_bits]),
            ];
            let out = self.probe.run(&inputs)?;
            loss += out[0].as_f32()?[0] as f64;
            grads.row_mut(w).copy_from_slice(out[1].as_f32()?);
        }
        loss /= self.workers as f64;

        // Quantized all-reduce: each worker's gradient is a sample row.
        let reduced: Vec<f32> = if self.allreduce_bits > 0.0 && self.workers > 1 {
            let _qsp = obs::span("dp/allreduce_quant");
            let q = self.quantizer.apply(&grads, self.allreduce_bits, rng);
            mean_rows(&q)
        } else {
            mean_rows(&grads)
        };

        let mut gnorm = 0.0f64;
        for ((pv, vv), g) in params.iter_mut().zip(velocity.iter_mut()).zip(&reduced) {
            gnorm += f64::from(*g) * f64::from(*g);
            *vv = (self.momentum * f64::from(*vv) + f64::from(*g)) as f32;
            *pv -= (lr * f64::from(*vv)) as f32;
        }
        if obs::enabled() {
            let m = obs::metrics();
            m.counter("dp_steps_total", "data-parallel steps").inc();
            m.gauge("dp_grad_norm_sq", "squared norm of the last reduced gradient")
                .set(gnorm);
        }
        Ok(DpStep {
            loss,
            grad_norm_sq: gnorm,
        })
    }

    /// Convenience full run (used by the ablation experiments).
    #[allow(clippy::too_many_arguments)]
    pub fn train(
        &self,
        dataset: &dyn Dataset,
        params: &mut Vec<f32>,
        steps: u64,
        base_lr: f64,
        schedule: Schedule,
        warmup: u64,
        model_bits: f32,
        seed: u64,
    ) -> Result<Vec<DpStep>> {
        let mut velocity = vec![0.0f32; params.len()];
        let mut rng = Pcg32::new(seed, 404);
        let mut out = Vec::with_capacity(steps as usize);
        for step in 0..steps {
            let lr = schedule.lr(base_lr, step, steps, warmup);
            let s = self.step(
                dataset,
                params,
                &mut velocity,
                step,
                lr,
                model_bits,
                &mut rng,
            )?;
            out.push(s);
        }
        Ok(out)
    }
}

fn mean_rows(m: &Mat) -> Vec<f32> {
    let mut out = vec![0.0f32; m.cols];
    let inv = 1.0 / m.rows as f32;
    for i in 0..m.rows {
        for (o, &v) in out.iter_mut().zip(m.row(i)) {
            *o += v * inv;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_rows_averages() {
        let m = Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, 3.0, 2.0, 1.0]);
        assert_eq!(mean_rows(&m), vec![2.0, 2.0, 2.0]);
    }

    /// Regression: the seed formula `(step * 1009 + w) as f32` collapses
    /// adjacent workers to one float once step*1009 exceeds 2^24 (f32 has
    /// 24 mantissa bits), so all workers drew identical SR noise. The
    /// mixed seeds must stay distinct at any step count.
    #[test]
    fn worker_seeds_distinct_at_large_steps() {
        // demonstrate the seed bug first: the old formula collides
        let old = |step: u64, w: u64| (step * 1009 + w) as f32;
        assert_eq!(old(1 << 30, 0), old(1 << 30, 1));
        assert_ne!(worker_seed(1 << 30, 0), worker_seed(1 << 30, 1));

        let steps: [u64; 14] = [
            0,
            1,
            2,
            3,
            (1 << 24) - 1,
            1 << 24,
            (1 << 24) + 1,
            1 << 25,
            1 << 30,
            1 << 31,
            1 << 40,
            1 << 48,
            1 << 52,
            1 << 63,
        ];
        let mut seen = std::collections::HashSet::new();
        for &s in &steps {
            for w in 0..16usize {
                seen.insert(worker_seed(s, w));
            }
        }
        assert_eq!(seen.len(), steps.len() * 16, "seed collision in grid");
        // the f32 bit-carriers are distinct too (compare bits — some
        // patterns may be NaN, where == would lie)
        assert_ne!(
            f32::from_bits(worker_seed(1 << 30, 0)).to_bits(),
            f32::from_bits(worker_seed(1 << 30, 1)).to_bits()
        );
    }

    /// Pinned reference values: the mix must stay stable across
    /// refactors, or seeded runs stop replaying.
    #[test]
    fn worker_seed_reference_vectors() {
        assert_eq!(worker_seed(0, 0), 3_793_791_033);
        assert_eq!(worker_seed(1, 0), 1_853_398_634);
        assert_eq!(worker_seed(1 << 30, 0), 2_192_442_695);
        assert_eq!(worker_seed(1 << 30, 1), 1_923_593_825);
        assert_eq!(worker_seed(1 << 24, 3), 2_313_681_756);
        assert_eq!(worker_seed(1 << 52, 7), 726_271_972);
    }
}
