//! Hierarchical spans: scoped RAII timers recording into per-thread ring
//! buffers, exported in the Chrome trace event format (`trace.json`,
//! loadable in `chrome://tracing` / Perfetto).
//!
//! Each thread owns a lock-free-in-practice ring (its mutex is only ever
//! contended by the exporter); rings register themselves in a global sink
//! list on first use, so [`snapshot_events`] sees every thread. Spans are
//! emitted as complete `"X"` events (one record at drop — no B/E pairing
//! to leave unbalanced on early return), instants as `"i"`. When obs is
//! disabled ([`crate::obs::set_enabled`]) [`span`] is inert: no clock
//! read, no allocation, just the flag load.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::util::json::{obj, Json};

/// Per-thread ring capacity; the oldest events are overwritten beyond it.
pub const RING_CAP: usize = 1 << 15;

/// One recorded trace event.
#[derive(Clone, Debug)]
pub struct SpanEvent {
    pub name: String,
    pub cat: &'static str,
    /// `'X'` = complete span (has `dur`), `'i'` = instant.
    pub ph: char,
    /// Microseconds since the process trace epoch.
    pub ts_us: f64,
    pub dur_us: f64,
    pub tid: u64,
    pub args: Vec<(String, String)>,
}

#[derive(Default)]
struct Ring {
    events: Vec<SpanEvent>,
    next: usize,
    dropped: u64,
}

impl Ring {
    fn push(&mut self, e: SpanEvent) {
        if self.events.len() < RING_CAP {
            self.events.push(e);
        } else {
            self.events[self.next] = e;
            self.next = (self.next + 1) % RING_CAP;
            self.dropped += 1;
        }
    }
}

struct ThreadBuf {
    tid: u64,
    ring: Mutex<Ring>,
}

static SINKS: OnceLock<Mutex<Vec<Arc<ThreadBuf>>>> = OnceLock::new();
static NEXT_TID: AtomicU64 = AtomicU64::new(1);
static EPOCH: OnceLock<Instant> = OnceLock::new();

fn sinks() -> &'static Mutex<Vec<Arc<ThreadBuf>>> {
    SINKS.get_or_init(|| Mutex::new(Vec::new()))
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

fn now_us() -> f64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_secs_f64() * 1e6
}

thread_local! {
    static LOCAL: Arc<ThreadBuf> = {
        let buf = Arc::new(ThreadBuf {
            tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
            ring: Mutex::new(Ring::default()),
        });
        lock(sinks()).push(buf.clone());
        buf
    };
}

fn record(mut e: SpanEvent) {
    LOCAL.with(|b| {
        e.tid = b.tid;
        lock(&b.ring).push(e);
    });
}

struct Active {
    name: &'static str,
    cat: &'static str,
    start_us: f64,
}

/// RAII span: records one complete event covering its lifetime on drop.
#[must_use = "a span guard measures until it is dropped"]
pub struct SpanGuard(Option<Active>);

/// Open a span in the default category. Inert when obs is disabled.
#[inline]
pub fn span(name: &'static str) -> SpanGuard {
    span_cat(name, "app")
}

/// Open a span with an explicit Chrome trace category.
#[inline]
pub fn span_cat(name: &'static str, cat: &'static str) -> SpanGuard {
    if !crate::obs::enabled() {
        return SpanGuard(None);
    }
    SpanGuard(Some(Active {
        name,
        cat,
        start_us: now_us(),
    }))
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some(a) = self.0.take() {
            let end = now_us();
            record(SpanEvent {
                name: a.name.to_string(),
                cat: a.cat,
                ph: 'X',
                ts_us: a.start_us,
                dur_us: (end - a.start_us).max(0.0),
                tid: 0,
                args: Vec::new(),
            });
        }
    }
}

/// Record a zero-duration instant event with structured args — the obs
/// event stream (divergence, checkpoint writes, ...).
pub fn instant(name: &str, args: &[(&str, String)]) {
    if !crate::obs::enabled() {
        return;
    }
    record(SpanEvent {
        name: name.to_string(),
        cat: "event",
        ph: 'i',
        ts_us: now_us(),
        dur_us: 0.0,
        tid: 0,
        args: args.iter().map(|(k, v)| (k.to_string(), v.clone())).collect(),
    });
}

/// Copy of every recorded event across all threads, sorted by start time.
pub fn snapshot_events() -> Vec<SpanEvent> {
    let bufs: Vec<Arc<ThreadBuf>> = lock(sinks()).clone();
    let mut out = Vec::new();
    for b in bufs {
        out.extend(lock(&b.ring).events.iter().cloned());
    }
    out.sort_by(|a, b| a.ts_us.total_cmp(&b.ts_us));
    out
}

/// Total events dropped to ring overwrites (all threads).
pub fn dropped_events() -> u64 {
    let bufs: Vec<Arc<ThreadBuf>> = lock(sinks()).clone();
    bufs.iter().map(|b| lock(&b.ring).dropped).sum()
}

/// Drop all recorded events (test isolation / per-run trace windows).
pub fn clear() {
    let bufs: Vec<Arc<ThreadBuf>> = lock(sinks()).clone();
    for b in bufs {
        let mut r = lock(&b.ring);
        r.events.clear();
        r.next = 0;
        r.dropped = 0;
    }
}

/// Encode events as a Chrome trace-event-format document.
pub fn chrome_trace(events: &[SpanEvent]) -> Json {
    let mut list = Vec::with_capacity(events.len());
    for e in events {
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::from(e.name.clone()));
        m.insert("cat".to_string(), Json::from(e.cat));
        m.insert("ph".to_string(), Json::from(e.ph.to_string()));
        m.insert("ts".to_string(), Json::Num(e.ts_us));
        if e.ph == 'X' {
            m.insert("dur".to_string(), Json::Num(e.dur_us));
        }
        if e.ph == 'i' {
            // instant scope: thread
            m.insert("s".to_string(), Json::from("t"));
        }
        m.insert("pid".to_string(), Json::from(1usize));
        m.insert("tid".to_string(), Json::from(e.tid as usize));
        if !e.args.is_empty() {
            m.insert(
                "args".to_string(),
                Json::Obj(
                    e.args
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::from(v.clone())))
                        .collect(),
                ),
            );
        }
        list.push(Json::Obj(m));
    }
    obj([
        ("displayTimeUnit", Json::from("ms")),
        ("traceEvents", Json::Arr(list)),
    ])
}

/// Write the current global snapshot as `trace.json` at `path`.
pub fn write_chrome_trace(path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let doc = chrome_trace(&snapshot_events());
    std::fs::write(path, doc.to_string_pretty())
        .with_context(|| format!("writing {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_nest_and_export_as_complete_events() {
        let _g = crate::obs::testutil::serial();
        crate::obs::set_enabled(true);
        clear();
        {
            let _outer = span("span_test/outer");
            {
                let _inner = span("span_test/inner");
                std::hint::black_box(0u64);
            }
        }
        instant("span_test/mark", &[("k", "v".to_string())]);
        let evs = snapshot_events();
        let outer = evs.iter().find(|e| e.name == "span_test/outer").unwrap();
        let inner = evs.iter().find(|e| e.name == "span_test/inner").unwrap();
        assert_eq!(outer.ph, 'X');
        assert_eq!(inner.ph, 'X');
        // inner starts no earlier and is no longer than outer
        assert!(inner.ts_us >= outer.ts_us);
        assert!(inner.dur_us <= outer.dur_us);
        let mark = evs.iter().find(|e| e.name == "span_test/mark").unwrap();
        assert_eq!(mark.ph, 'i');
        assert_eq!(mark.args, vec![("k".to_string(), "v".to_string())]);
    }

    #[test]
    fn chrome_trace_parses_with_own_json_codec() {
        let _g = crate::obs::testutil::serial();
        crate::obs::set_enabled(true);
        clear();
        {
            let _s = span("span_test/chrome");
        }
        let doc = chrome_trace(&snapshot_events());
        let text = doc.to_string_pretty();
        let back = Json::parse(&text).expect("trace.json must parse");
        let evs = back.get("traceEvents").and_then(Json::as_arr).unwrap();
        let e = evs
            .iter()
            .find(|e| e.get("name").and_then(Json::as_str) == Some("span_test/chrome"))
            .unwrap();
        assert_eq!(e.get("ph").and_then(Json::as_str), Some("X"));
        assert!(e.get("dur").and_then(Json::as_f64).is_some());
        assert!(e.get("ts").and_then(Json::as_f64).is_some());
    }

    #[test]
    fn disabled_spans_record_nothing() {
        let _g = crate::obs::testutil::serial();
        crate::obs::set_enabled(true);
        clear();
        crate::obs::set_enabled(false);
        {
            let _s = span("span_test/disabled");
        }
        instant("span_test/disabled_i", &[]);
        crate::obs::set_enabled(true);
        assert!(snapshot_events()
            .iter()
            .all(|e| !e.name.starts_with("span_test/disabled")));
    }

    #[test]
    fn ring_overwrites_oldest_beyond_capacity() {
        let _g = crate::obs::testutil::serial();
        crate::obs::set_enabled(true);
        clear();
        for _ in 0..RING_CAP + 10 {
            let _s = span("span_test/ring");
        }
        let n = snapshot_events()
            .iter()
            .filter(|e| e.name == "span_test/ring")
            .count();
        assert!(n <= RING_CAP);
        assert!(dropped_events() >= 10);
        clear();
    }
}
