//! Quantizer telemetry: clip / zero-code / poisoned-row counters plus
//! sampled exact SR-variance gauges — the Theorem-1 quantization-noise
//! quantities, observed live instead of via an offline probe.
//!
//! Each native quantizer (`quant::{ptq,psq,bhq,sr}`) reports one
//! [`crate::quant::QuantStats`] per call through its per-quantizer
//! [`QuantTelemetry`]; counts land in labeled registry counters
//! (`quant_*_total{quantizer="ptq"}`), and every
//! [`SAMPLE_EVERY`]-th call additionally computes the exact SR variance
//! sum p(1-p)/scale^2 (Proposition 4) which feeds a last-value gauge and
//! a Welford running mean ([`crate::stats::Welford`]).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

use crate::quant::QuantStats;
use crate::stats::Welford;

use super::registry::{labeled, Counter, Gauge};

/// Every `SAMPLE_EVERY`-th quantize call pays for the exact-variance
/// pass; the counters are exact on every call.
pub const SAMPLE_EVERY: u64 = 16;

/// Per-quantizer telemetry sink.
pub struct QuantTelemetry {
    pub name: &'static str,
    tensors: Counter,
    values: Counter,
    clipped: Counter,
    zero_codes: Counter,
    poisoned_rows: Counter,
    calls: AtomicU64,
    var_last: Gauge,
    var_mean: Gauge,
    welford: Mutex<Welford>,
}

impl QuantTelemetry {
    fn new(name: &'static str) -> Self {
        let m = crate::obs::metrics();
        let l = |base: &str| labeled(base, &[("quantizer", name)]);
        Self {
            name,
            tensors: m.counter(&l("quant_tensors_total"), "tensors quantized"),
            values: m.counter(&l("quant_values_total"), "scalar values quantized"),
            clipped: m.counter(&l("quant_clipped_total"), "codes clipped into the bin range"),
            zero_codes: m.counter(&l("quant_zero_codes_total"), "codes that landed on zero"),
            poisoned_rows: m.counter(&l("quant_poisoned_rows_total"), "NaN-poisoned rows emitted"),
            calls: AtomicU64::new(0),
            var_last: m.gauge(
                &l("quant_sr_variance"),
                "exact SR variance of the last sampled tensor (Thm 1 noise term)",
            ),
            var_mean: m.gauge(
                &l("quant_sr_variance_mean"),
                "running mean of sampled exact SR variances",
            ),
            welford: Mutex::new(Welford::new()),
        }
    }

    /// Whether this call should compute the exact-variance sample. Also
    /// advances the call counter, so call it exactly once per quantize.
    #[inline]
    pub fn should_sample(&self) -> bool {
        crate::obs::enabled() && self.calls.fetch_add(1, Ordering::Relaxed) % SAMPLE_EVERY == 0
    }

    /// Fold one quantize call's stats into the counters and gauges.
    pub fn record(&self, st: &QuantStats) {
        if !crate::obs::enabled() {
            return;
        }
        self.tensors.inc();
        self.values.add(st.values);
        self.clipped.add(st.clipped);
        self.zero_codes.add(st.zero_codes);
        self.poisoned_rows.add(st.poisoned_rows);
        if let Some(v) = st.sr_variance {
            if v.is_finite() {
                self.var_last.set(v);
                let mut w = self.welford.lock().unwrap_or_else(|e| e.into_inner());
                w.push(v);
                self.var_mean.set(w.mean());
            }
        }
    }

    pub fn totals(&self) -> QuantTotals {
        QuantTotals {
            tensors: self.tensors.get(),
            values: self.values.get(),
            clipped: self.clipped.get(),
            zero_codes: self.zero_codes.get(),
            poisoned_rows: self.poisoned_rows.get(),
            var_last: self.var_last.get(),
            var_mean: self.var_mean.get(),
        }
    }
}

macro_rules! telemetry_static {
    ($fn_name:ident, $name:literal) => {
        pub fn $fn_name() -> &'static QuantTelemetry {
            static CELL: OnceLock<QuantTelemetry> = OnceLock::new();
            CELL.get_or_init(|| QuantTelemetry::new($name))
        }
    };
}

telemetry_static!(ptq, "ptq");
telemetry_static!(psq, "psq");
telemetry_static!(bhq, "bhq");
telemetry_static!(sr, "sr");

/// Count one integer-path fallback: a quantizer without an integer-code
/// entry point (BHQ/FP8/BFP, or a bitwidth outside the i8 gate) was
/// asked for codes and the caller reverted to the dequant path. Lands in
/// `quant_int_fallback_total{quantizer="..."}`.
pub fn int_fallback(name: &str) {
    if !crate::obs::enabled() {
        return;
    }
    crate::obs::metrics()
        .counter(
            &labeled("quant_int_fallback_total", &[("quantizer", name)]),
            "integer-code path fallbacks to the dequant path",
        )
        .inc();
}

/// Telemetry sink for a quantizer name, if one is instrumented.
pub fn by_name(name: &str) -> Option<&'static QuantTelemetry> {
    match name {
        "ptq" => Some(ptq()),
        "psq" => Some(psq()),
        "bhq" => Some(bhq()),
        "sr" => Some(sr()),
        _ => None,
    }
}

/// Point-in-time totals for one quantizer (or summed over all).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct QuantTotals {
    pub tensors: u64,
    pub values: u64,
    pub clipped: u64,
    pub zero_codes: u64,
    pub poisoned_rows: u64,
    pub var_last: f64,
    pub var_mean: f64,
}

impl QuantTotals {
    pub fn clip_rate(&self) -> f64 {
        if self.values == 0 {
            0.0
        } else {
            self.clipped as f64 / self.values as f64
        }
    }

    pub fn zero_rate(&self) -> f64 {
        if self.values == 0 {
            0.0
        } else {
            self.zero_codes as f64 / self.values as f64
        }
    }

    /// Count deltas since `earlier`; gauges keep `self`'s (latest) values.
    pub fn since(&self, earlier: &QuantTotals) -> QuantTotals {
        QuantTotals {
            tensors: self.tensors.saturating_sub(earlier.tensors),
            values: self.values.saturating_sub(earlier.values),
            clipped: self.clipped.saturating_sub(earlier.clipped),
            zero_codes: self.zero_codes.saturating_sub(earlier.zero_codes),
            poisoned_rows: self.poisoned_rows.saturating_sub(earlier.poisoned_rows),
            var_last: self.var_last,
            var_mean: self.var_mean,
        }
    }
}

/// Totals for a run variant: the named quantizer's own telemetry when it
/// is instrumented, otherwise (qat/exact/fp8/bfp) the sum over all sinks
/// — whatever quantization the variant exercised indirectly.
pub fn totals_for(variant: &str) -> QuantTotals {
    if let Some(t) = by_name(variant) {
        return t.totals();
    }
    let mut acc = QuantTotals::default();
    for t in [ptq(), psq(), bhq(), sr()] {
        let x = t.totals();
        acc.tensors += x.tensors;
        acc.values += x.values;
        acc.clipped += x.clipped;
        acc.zero_codes += x.zero_codes;
        acc.poisoned_rows += x.poisoned_rows;
        if x.var_last != 0.0 {
            acc.var_last = x.var_last;
            acc.var_mean = x.var_mean;
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    // Tests use uniquely-named instances: the ptq/psq/bhq/sr singletons
    // receive concurrent traffic from quantizer tests in other threads,
    // so exact-count assertions against them would be racy.

    #[test]
    fn record_accumulates_and_rates_compute() {
        let _g = crate::obs::testutil::serial();
        crate::obs::set_enabled(true);
        let tel = QuantTelemetry::new("test_q_record");
        let before = tel.totals();
        tel.record(&QuantStats {
            values: 100,
            clipped: 5,
            zero_codes: 20,
            poisoned_rows: 1,
            sr_variance: Some(0.25),
        });
        let delta = tel.totals().since(&before);
        assert_eq!(delta.tensors, 1);
        assert_eq!(delta.values, 100);
        assert_eq!(delta.clipped, 5);
        assert_eq!(delta.zero_codes, 20);
        assert_eq!(delta.poisoned_rows, 1);
        assert_eq!(delta.clip_rate(), 0.05);
        assert_eq!(delta.zero_rate(), 0.2);
        assert_eq!(delta.var_last, 0.25);
    }

    #[test]
    fn sampling_cadence_is_one_in_sample_every() {
        let _g = crate::obs::testutil::serial();
        crate::obs::set_enabled(true);
        let tel = QuantTelemetry::new("test_q_cadence");
        assert!(tel.should_sample(), "first call must sample");
        let sampled = (1..SAMPLE_EVERY).filter(|_| tel.should_sample()).count();
        assert_eq!(sampled, 0, "rest of the window must not sample");
        assert!(tel.should_sample(), "next window samples again");
    }

    #[test]
    fn disabled_never_samples_or_records() {
        let _g = crate::obs::testutil::serial();
        let tel = QuantTelemetry::new("test_q_disabled");
        crate::obs::set_enabled(false);
        let before = tel.totals();
        assert!(!tel.should_sample());
        tel.record(&QuantStats {
            values: 10,
            ..QuantStats::default()
        });
        crate::obs::set_enabled(true);
        assert_eq!(tel.totals(), before);
    }

    #[test]
    fn totals_for_falls_back_to_sum() {
        let _g = crate::obs::testutil::serial();
        crate::obs::set_enabled(true);
        let before = totals_for("qat");
        sr().record(&QuantStats {
            values: 7,
            clipped: 2,
            ..QuantStats::default()
        });
        let after = totals_for("qat");
        assert!(after.values >= before.values + 7);
        assert!(after.clipped >= before.clipped + 2);
        assert!(by_name("ptq").is_some());
        assert!(by_name("qat").is_none());
    }
}
