//! Metrics registry: atomic counters, gauges, and fixed-bin histograms
//! with Prometheus-style text exposition and a JSON snapshot exporter.
//!
//! Handles ([`Counter`], [`Gauge`], [`HistogramMetric`]) are `Arc`-backed
//! cells resolved once at registration time; the hot path is one relaxed
//! atomic load (the global enable flag, [`crate::obs::enabled`]) plus one
//! relaxed RMW. With obs disabled every record call reduces to the single
//! flag load — the "compiles to atomic loads only" budget the overhead
//! bench (`benches/obs_overhead.rs`) verifies.
//!
//! Naming convention: `snake_case` bases with Prometheus suffixes
//! (`_total` for counters, `_seconds`/`_ns` for timings) and inline
//! labels built via [`labeled`], e.g.
//! `quant_clipped_total{quantizer="ptq"}`. The full labeled string is the
//! registry key, so two label sets of one base are two independent cells
//! sharing one `# HELP`/`# TYPE` block in the exposition.

use std::collections::{BTreeMap, BTreeSet};
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{SystemTime, UNIX_EPOCH};

use crate::util::json::{obj, Json};

/// Exponential wall-time buckets (seconds) shared by the latency
/// histograms: 1 µs .. 10 s.
pub const TIME_BUCKETS: [f64; 10] = [1e-6, 1e-5, 1e-4, 5e-4, 1e-3, 5e-3, 1e-2, 1e-1, 1.0, 10.0];

/// Build a full metric name with Prometheus labels:
/// `labeled("quant_clipped_total", &[("quantizer", "ptq")])` yields
/// `quant_clipped_total{quantizer="ptq"}`.
pub fn labeled(base: &str, labels: &[(&str, &str)]) -> String {
    let mut s = String::with_capacity(base.len() + 24 * labels.len());
    s.push_str(base);
    s.push('{');
    for (i, (k, v)) in labels.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        let _ = write!(s, "{k}=\"{}\"", v.replace('\\', "\\\\").replace('"', "\\\""));
    }
    s.push('}');
    s
}

/// Name without the label block (`a_total{x="y"}` -> `a_total`).
fn base_of(name: &str) -> &str {
    name.split('{').next().unwrap_or(name)
}

/// Monotonic counter. `Clone` shares the underlying cell.
#[derive(Clone, Debug, Default)]
pub struct Counter {
    cell: Arc<AtomicU64>,
}

impl Counter {
    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        if crate::obs::enabled() {
            self.cell.fetch_add(n, Ordering::Relaxed);
        }
    }

    pub fn get(&self) -> u64 {
        self.cell.load(Ordering::Relaxed)
    }

    fn reset(&self) {
        self.cell.store(0, Ordering::Relaxed);
    }
}

/// Last-value gauge (f64 bits in an atomic).
#[derive(Clone, Debug, Default)]
pub struct Gauge {
    cell: Arc<AtomicU64>,
}

impl Gauge {
    #[inline]
    pub fn set(&self, v: f64) {
        if crate::obs::enabled() {
            self.cell.store(v.to_bits(), Ordering::Relaxed);
        }
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.cell.load(Ordering::Relaxed))
    }

    fn reset(&self) {
        self.cell.store(0, Ordering::Relaxed);
    }
}

#[derive(Debug)]
struct HistCore {
    /// Sorted, deduped upper bounds; counts has one extra overflow slot.
    bounds: Vec<f64>,
    counts: Vec<AtomicU64>,
    total: AtomicU64,
    sum_bits: AtomicU64,
}

/// Fixed-bin histogram with Prometheus cumulative-bucket exposition.
#[derive(Clone, Debug)]
pub struct HistogramMetric {
    core: Arc<HistCore>,
}

impl HistogramMetric {
    fn new(bounds: &[f64]) -> Self {
        let mut b: Vec<f64> = bounds.iter().copied().filter(|v| v.is_finite()).collect();
        b.sort_by(f64::total_cmp);
        b.dedup();
        let counts = (0..=b.len()).map(|_| AtomicU64::new(0)).collect();
        Self {
            core: Arc::new(HistCore {
                bounds: b,
                counts,
                total: AtomicU64::new(0),
                sum_bits: AtomicU64::new(0),
            }),
        }
    }

    #[inline]
    pub fn observe(&self, v: f64) {
        if !crate::obs::enabled() {
            return;
        }
        let c = &self.core;
        // first bound >= v, i.e. the `le` bucket this value falls in
        let idx = c.bounds.partition_point(|&b| b < v);
        c.counts[idx].fetch_add(1, Ordering::Relaxed);
        c.total.fetch_add(1, Ordering::Relaxed);
        let mut cur = c.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match c
                .sum_bits
                .compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => break,
                Err(actual) => cur = actual,
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.core.total.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.core.sum_bits.load(Ordering::Relaxed))
    }

    /// `(upper_bound, cumulative_count)` pairs, ending with `(+inf, total)`.
    pub fn cumulative(&self) -> Vec<(f64, u64)> {
        let c = &self.core;
        let mut out = Vec::with_capacity(c.bounds.len() + 1);
        let mut acc = 0u64;
        for (i, &b) in c.bounds.iter().enumerate() {
            acc += c.counts[i].load(Ordering::Relaxed);
            out.push((b, acc));
        }
        acc += c.counts[c.bounds.len()].load(Ordering::Relaxed);
        out.push((f64::INFINITY, acc));
        out
    }

    fn reset(&self) {
        for c in &self.core.counts {
            c.store(0, Ordering::Relaxed);
        }
        self.core.total.store(0, Ordering::Relaxed);
        self.core.sum_bits.store(0, Ordering::Relaxed);
    }
}

enum Entry {
    Counter(Counter),
    Gauge(Gauge),
    Histogram(HistogramMetric),
}

struct Slot {
    help: String,
    entry: Entry,
}

/// The registry: labeled name -> metric cell. One global instance lives
/// behind [`crate::obs::metrics`]; tests construct their own.
#[derive(Default)]
pub struct MetricsRegistry {
    inner: Mutex<BTreeMap<String, Slot>>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    fn lock(&self) -> MutexGuard<'_, BTreeMap<String, Slot>> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Get-or-register a counter. On a name already registered with a
    /// different type, returns a detached cell (recorded values go
    /// nowhere) rather than panicking mid-training.
    pub fn counter(&self, name: &str, help: &str) -> Counter {
        let mut m = self.lock();
        let slot = m.entry(name.to_string()).or_insert_with(|| Slot {
            help: help.to_string(),
            entry: Entry::Counter(Counter::default()),
        });
        match &slot.entry {
            Entry::Counter(c) => c.clone(),
            _ => Counter::default(),
        }
    }

    pub fn gauge(&self, name: &str, help: &str) -> Gauge {
        let mut m = self.lock();
        let slot = m.entry(name.to_string()).or_insert_with(|| Slot {
            help: help.to_string(),
            entry: Entry::Gauge(Gauge::default()),
        });
        match &slot.entry {
            Entry::Gauge(g) => g.clone(),
            _ => Gauge::default(),
        }
    }

    pub fn histogram(&self, name: &str, help: &str, bounds: &[f64]) -> HistogramMetric {
        let mut m = self.lock();
        let slot = m.entry(name.to_string()).or_insert_with(|| Slot {
            help: help.to_string(),
            entry: Entry::Histogram(HistogramMetric::new(bounds)),
        });
        match &slot.entry {
            Entry::Histogram(h) => h.clone(),
            _ => HistogramMetric::new(bounds),
        }
    }

    /// Zero every registered cell (handles stay valid). Test isolation.
    pub fn reset(&self) {
        for slot in self.lock().values() {
            match &slot.entry {
                Entry::Counter(c) => c.reset(),
                Entry::Gauge(g) => g.reset(),
                Entry::Histogram(h) => h.reset(),
            }
        }
    }

    /// Prometheus text exposition format: `# HELP`/`# TYPE` per base
    /// name, histogram `_bucket{le=...}`/`_sum`/`_count` expansion.
    pub fn render_prometheus(&self) -> String {
        let m = self.lock();
        let mut out = String::new();
        let mut seen: BTreeSet<&str> = BTreeSet::new();
        for (name, slot) in m.iter() {
            let base = base_of(name);
            if seen.insert(base) {
                let kind = match slot.entry {
                    Entry::Counter(_) => "counter",
                    Entry::Gauge(_) => "gauge",
                    Entry::Histogram(_) => "histogram",
                };
                let _ = writeln!(out, "# HELP {base} {}", slot.help);
                let _ = writeln!(out, "# TYPE {base} {kind}");
            }
            match &slot.entry {
                Entry::Counter(c) => {
                    let _ = writeln!(out, "{name} {}", c.get());
                }
                Entry::Gauge(g) => {
                    let _ = writeln!(out, "{name} {}", g.get());
                }
                Entry::Histogram(h) => {
                    let labels = &name[base.len()..];
                    let inner = labels.trim_start_matches('{').trim_end_matches('}');
                    for (le, cum) in h.cumulative() {
                        let le_s = if le.is_infinite() {
                            "+Inf".to_string()
                        } else {
                            format!("{le}")
                        };
                        if inner.is_empty() {
                            let _ = writeln!(out, "{base}_bucket{{le=\"{le_s}\"}} {cum}");
                        } else {
                            let _ = writeln!(out, "{base}_bucket{{{inner},le=\"{le_s}\"}} {cum}");
                        }
                    }
                    let _ = writeln!(out, "{base}_sum{labels} {}", h.sum());
                    let _ = writeln!(out, "{base}_count{labels} {}", h.count());
                }
            }
        }
        out
    }

    /// One snapshot of every metric as a JSON object — the payload of
    /// the `metrics.jsonl` exporter and the `BENCH_*.json` trajectories.
    pub fn snapshot_json(&self) -> Json {
        let m = self.lock();
        let mut counters = BTreeMap::new();
        let mut gauges = BTreeMap::new();
        let mut hists = BTreeMap::new();
        for (name, slot) in m.iter() {
            match &slot.entry {
                Entry::Counter(c) => {
                    counters.insert(name.clone(), Json::Num(c.get() as f64));
                }
                Entry::Gauge(g) => {
                    gauges.insert(name.clone(), json_num(g.get()));
                }
                Entry::Histogram(h) => {
                    let buckets: Vec<Json> = h
                        .cumulative()
                        .into_iter()
                        .map(|(le, n)| {
                            let le_j = if le.is_infinite() {
                                Json::Str("+Inf".to_string())
                            } else {
                                Json::Num(le)
                            };
                            let fields = [
                                ("le".to_string(), le_j),
                                ("count".to_string(), Json::from(n as f64)),
                            ];
                            Json::Obj(fields.into_iter().collect())
                        })
                        .collect();
                    hists.insert(
                        name.clone(),
                        Json::Obj(
                            [
                                ("count".to_string(), Json::from(h.count() as f64)),
                                ("sum".to_string(), json_num(h.sum())),
                                ("buckets".to_string(), Json::Arr(buckets)),
                            ]
                            .into_iter()
                            .collect(),
                        ),
                    );
                }
            }
        }
        let ts = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_millis() as f64)
            .unwrap_or(0.0);
        obj([
            ("ts_unix_ms", Json::Num(ts)),
            ("counters", Json::Obj(counters)),
            ("gauges", Json::Obj(gauges)),
            ("histograms", Json::Obj(hists)),
        ])
    }
}

/// Non-finite f64 (NaN gauge, inf sum) would serialize as invalid JSON;
/// encode it as its display string instead.
fn json_num(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Str(format!("{v}"))
    }
}

/// Parse a Prometheus text exposition back into `name -> value` samples
/// (comments and blank lines skipped). The value is everything after the
/// *last* space, so label values containing spaces survive.
pub fn parse_prometheus(text: &str) -> BTreeMap<String, f64> {
    let mut out = BTreeMap::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if let Some((name, val)) = line.rsplit_once(' ') {
            if let Ok(v) = val.parse::<f64>() {
                out.insert(name.trim().to_string(), v);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labeled_escapes_and_formats() {
        assert_eq!(
            labeled("x_total", &[("a", "b"), ("c", "d\"e")]),
            "x_total{a=\"b\",c=\"d\\\"e\"}"
        );
    }

    #[test]
    fn exposition_roundtrips_through_line_parser() {
        let _g = crate::obs::testutil::serial();
        crate::obs::set_enabled(true);
        let r = MetricsRegistry::new();
        let c = r.counter("steps_total", "steps done");
        let cl = r.counter(&labeled("clip_total", &[("quantizer", "ptq")]), "clips");
        let g = r.gauge("loss", "last loss");
        let h = r.histogram("lat_seconds", "latency", &[0.001, 0.01, 0.1]);
        c.add(7);
        cl.add(3);
        g.set(2.5);
        h.observe(0.0005);
        h.observe(0.05);
        h.observe(99.0);

        let text = r.render_prometheus();
        assert!(text.contains("# TYPE steps_total counter"));
        assert!(text.contains("# TYPE lat_seconds histogram"));
        let map = parse_prometheus(&text);
        assert_eq!(map["steps_total"], 7.0);
        assert_eq!(map["clip_total{quantizer=\"ptq\"}"], 3.0);
        assert_eq!(map["loss"], 2.5);
        // cumulative buckets: 0.0005 <= 0.001; 0.05 <= 0.1; 99 -> +Inf
        assert_eq!(map["lat_seconds_bucket{le=\"0.001\"}"], 1.0);
        assert_eq!(map["lat_seconds_bucket{le=\"0.01\"}"], 1.0);
        assert_eq!(map["lat_seconds_bucket{le=\"0.1\"}"], 2.0);
        assert_eq!(map["lat_seconds_bucket{le=\"+Inf\"}"], 3.0);
        assert_eq!(map["lat_seconds_count"], 3.0);
        assert!((map["lat_seconds_sum"] - 99.0505).abs() < 1e-9);
    }

    #[test]
    fn labeled_histogram_buckets_carry_labels() {
        let _g = crate::obs::testutil::serial();
        crate::obs::set_enabled(true);
        let r = MetricsRegistry::new();
        let h = r.histogram(
            &labeled("disp_seconds", &[("backend", "native")]),
            "dispatch",
            &TIME_BUCKETS,
        );
        h.observe(2e-6);
        let map = parse_prometheus(&r.render_prometheus());
        assert_eq!(map["disp_seconds_bucket{backend=\"native\",le=\"0.00001\"}"], 1.0);
        assert_eq!(map["disp_seconds_count{backend=\"native\"}"], 1.0);
    }

    #[test]
    fn disabled_registry_records_nothing() {
        let _g = crate::obs::testutil::serial();
        let r = MetricsRegistry::new();
        let c = r.counter("c_total", "c");
        let g = r.gauge("g", "g");
        let h = r.histogram("h_seconds", "h", &TIME_BUCKETS);
        crate::obs::set_enabled(false);
        c.inc();
        g.set(5.0);
        h.observe(0.5);
        crate::obs::set_enabled(true);
        assert_eq!(c.get(), 0);
        assert_eq!(g.get(), 0.0);
        assert_eq!(h.count(), 0);
        c.inc();
        assert_eq!(c.get(), 1);
    }

    #[test]
    fn handles_share_cells_and_reset_zeroes() {
        let _g = crate::obs::testutil::serial();
        crate::obs::set_enabled(true);
        let r = MetricsRegistry::new();
        let a = r.counter("shared_total", "x");
        let b = r.counter("shared_total", "x");
        a.add(2);
        b.add(3);
        assert_eq!(a.get(), 5);
        r.reset();
        assert_eq!(b.get(), 0);
    }

    #[test]
    fn snapshot_json_is_parseable_even_with_nan_gauge() {
        let _g = crate::obs::testutil::serial();
        crate::obs::set_enabled(true);
        let r = MetricsRegistry::new();
        r.counter("a_total", "a").add(4);
        r.gauge("bad", "nan gauge").set(f64::NAN);
        r.histogram("h_seconds", "h", &[0.1]).observe(0.05);
        let snap = r.snapshot_json();
        let text = snap.to_string_pretty();
        let back = Json::parse(&text).expect("snapshot must be valid JSON");
        assert_eq!(back.path("counters.a_total").and_then(Json::as_f64), Some(4.0));
        assert_eq!(
            back.path("histograms.h_seconds.count").and_then(Json::as_f64),
            Some(1.0)
        );
        assert_eq!(back.path("gauges.bad").and_then(Json::as_str), Some("NaN"));
    }
}
