//! `statquant trace-report <run-dir>`: render per-phase time breakdowns
//! and quantizer health from a run directory's obs artifacts
//! (`trace.json`, `metrics.prom`, `log.jsonl`).
//!
//! Also the CI smoke gate: [`render_run_report`] fails hard when the
//! artifacts are missing, unparseable, or the trace event stream is
//! malformed (X events without `dur`, unbalanced B/E pairs).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::metrics::{fmt_sig, MarkdownTable};
use crate::util::json::Json;

use super::registry::parse_prometheus;

/// Aggregated timing for one span name.
#[derive(Clone, Debug, PartialEq)]
pub struct PhaseStat {
    pub name: String,
    pub count: u64,
    pub total_us: f64,
    pub max_us: f64,
}

impl PhaseStat {
    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.total_us / self.count as f64
        }
    }
}

/// Validate a Chrome trace document and aggregate complete (`"X"`)
/// events by name. Our exporter only emits X and i events, but foreign
/// traces are guarded too: an X event without `dur` or an unbalanced
/// B/E stream is an error, not a silent skip. Returns the per-phase
/// stats sorted by total time (desc) and the traced wall-clock span in
/// microseconds.
pub fn phase_breakdown(trace: &Json) -> Result<(Vec<PhaseStat>, f64)> {
    let events = trace
        .get("traceEvents")
        .and_then(Json::as_arr)
        .context("trace.json: missing traceEvents array")?;
    let mut agg: BTreeMap<String, PhaseStat> = BTreeMap::new();
    let mut begins: BTreeMap<String, i64> = BTreeMap::new();
    let mut t_min = f64::INFINITY;
    let mut t_max = f64::NEG_INFINITY;
    for (i, e) in events.iter().enumerate() {
        let ph = e
            .get("ph")
            .and_then(Json::as_str)
            .with_context(|| format!("trace event {i}: missing ph"))?;
        let name = e.get("name").and_then(Json::as_str).unwrap_or("?").to_string();
        let ts = e
            .get("ts")
            .and_then(Json::as_f64)
            .with_context(|| format!("trace event {i}: missing ts"))?;
        t_min = t_min.min(ts);
        t_max = t_max.max(ts);
        match ph {
            "X" => {
                let dur = e
                    .get("dur")
                    .and_then(Json::as_f64)
                    .with_context(|| format!("trace event {i} ({name}): X event without dur"))?;
                t_max = t_max.max(ts + dur);
                let s = agg.entry(name.clone()).or_insert_with(|| PhaseStat {
                    name,
                    count: 0,
                    total_us: 0.0,
                    max_us: 0.0,
                });
                s.count += 1;
                s.total_us += dur;
                s.max_us = s.max_us.max(dur);
            }
            "B" => *begins.entry(name).or_insert(0) += 1,
            "E" => *begins.entry(name).or_insert(0) -= 1,
            _ => {} // instant/metadata events only bound the window
        }
    }
    if let Some((name, n)) = begins.iter().find(|(_, &n)| n != 0) {
        bail!("trace.json: unbalanced B/E events for {name:?} (excess {n})");
    }
    let mut stats: Vec<PhaseStat> = agg.into_values().collect();
    stats.sort_by(|a, b| b.total_us.total_cmp(&a.total_us));
    let wall = if t_max > t_min { t_max - t_min } else { 0.0 };
    Ok((stats, wall))
}

/// Per-phase markdown table. `wall_us` normalizes the `% wall` column.
pub fn render_phase_table(stats: &[PhaseStat], wall_us: f64) -> String {
    let mut t = MarkdownTable::new(&["Phase", "Count", "Total ms", "Mean µs", "Max µs", "% wall"]);
    for s in stats {
        let share = if wall_us > 0.0 {
            100.0 * s.total_us / wall_us
        } else {
            0.0
        };
        t.row(vec![
            s.name.clone(),
            format!("{}", s.count),
            format!("{:.3}", s.total_us / 1e3),
            format!("{:.1}", s.mean_us()),
            format!("{:.1}", s.max_us),
            format!("{share:.1}"),
        ]);
    }
    t.render()
}

fn metric(map: &BTreeMap<String, f64>, base: &str, q: &str) -> f64 {
    map.get(&format!("{base}{{quantizer=\"{q}\"}}"))
        .copied()
        .unwrap_or(0.0)
}

/// Quantizer-health markdown table from parsed Prometheus samples.
pub fn render_quantizer_health(map: &BTreeMap<String, f64>) -> String {
    let mut names: Vec<String> = Vec::new();
    for k in map.keys() {
        if let Some(rest) = k.strip_prefix("quant_values_total{quantizer=\"") {
            if let Some(q) = rest.strip_suffix("\"}") {
                names.push(q.to_string());
            }
        }
    }
    if names.is_empty() {
        return "(no quantizer telemetry in metrics.prom)\n".to_string();
    }
    let mut t = MarkdownTable::new(&[
        "Quantizer",
        "Tensors",
        "Values",
        "Clipped",
        "Clip %",
        "Zero %",
        "Poisoned",
        "SR var (last)",
        "SR var (mean)",
    ]);
    for q in &names {
        let values = metric(map, "quant_values_total", q);
        let clipped = metric(map, "quant_clipped_total", q);
        let zeros = metric(map, "quant_zero_codes_total", q);
        let pct = |x: f64| if values > 0.0 { 100.0 * x / values } else { 0.0 };
        t.row(vec![
            q.clone(),
            format!("{}", metric(map, "quant_tensors_total", q)),
            format!("{values}"),
            format!("{clipped}"),
            format!("{:.3}", pct(clipped)),
            format!("{:.3}", pct(zeros)),
            format!("{}", metric(map, "quant_poisoned_rows_total", q)),
            fmt_sig(metric(map, "quant_sr_variance", q), 4),
            fmt_sig(metric(map, "quant_sr_variance_mean", q), 4),
        ]);
    }
    t.render()
}

/// Render the full report for one run directory. Errors if `trace.json`
/// or `metrics.prom` is missing, unparseable, or empty — this is the
/// contract the CI smoke step relies on.
pub fn render_run_report(dir: &Path) -> Result<String> {
    let trace_path = dir.join("trace.json");
    let trace_text = std::fs::read_to_string(&trace_path)
        .with_context(|| format!("reading {}", trace_path.display()))?;
    let trace = Json::parse(&trace_text)
        .map_err(|e| anyhow!("parsing {}: {e}", trace_path.display()))?;
    let (stats, wall) = phase_breakdown(&trace)?;
    if stats.is_empty() {
        bail!("{}: no complete span events recorded", trace_path.display());
    }

    let prom_path = dir.join("metrics.prom");
    let prom_text = std::fs::read_to_string(&prom_path)
        .with_context(|| format!("reading {}", prom_path.display()))?;
    let map = parse_prometheus(&prom_text);
    if map.is_empty() {
        bail!("{}: no metric samples", prom_path.display());
    }

    let mut out = String::new();
    out.push_str(&format!("# Trace report: {}\n\n", dir.display()));
    out.push_str(&format!(
        "Traced window: {:.3} ms, {} distinct phases\n\n",
        wall / 1e3,
        stats.len()
    ));
    out.push_str("## Per-phase time breakdown\n\n");
    out.push_str(&render_phase_table(&stats, wall));
    out.push_str("\n## Quantizer health\n\n");
    out.push_str(&render_quantizer_health(&map));

    // Run summary from the step log, when present.
    if let Ok(text) = std::fs::read_to_string(dir.join("log.jsonl")) {
        let mut last_eval: Option<Json> = None;
        let mut diverged_at: Option<u64> = None;
        let mut lines = 0u64;
        for line in text.lines().filter(|l| !l.trim().is_empty()) {
            let j = Json::parse(line)
                .map_err(|e| anyhow!("parsing {}: {e}", dir.join("log.jsonl").display()))?;
            lines += 1;
            if j.get("eval_loss").is_some() {
                last_eval = Some(j.clone());
            }
            if let Some(s) = j.get("diverged_at_step").and_then(Json::as_usize) {
                diverged_at = Some(s as u64);
            }
        }
        out.push_str("\n## Run summary\n\n");
        out.push_str(&format!("- log.jsonl records: {lines}\n"));
        if let Some(j) = last_eval {
            let g = |k: &str| j.get(k).and_then(Json::as_f64).unwrap_or(f64::NAN);
            out.push_str(&format!(
                "- last eval @ step {}: loss {}, acc {}, clip rate {}, grad var {}\n",
                g("step"),
                fmt_sig(g("eval_loss"), 4),
                fmt_sig(g("eval_acc"), 4),
                fmt_sig(g("quant_clip_rate"), 4),
                fmt_sig(g("quant_grad_var"), 4),
            ));
        }
        match diverged_at {
            Some(s) => out.push_str(&format!("- DIVERGED at step {s}\n")),
            None => out.push_str("- diverged: no\n"),
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace(events: &str) -> Json {
        Json::parse(&format!("{{\"traceEvents\":[{events}]}}")).unwrap()
    }

    #[test]
    fn aggregates_complete_events_by_name() {
        let t = trace(
            r#"{"name":"a","ph":"X","ts":0,"dur":10},
               {"name":"b","ph":"X","ts":2,"dur":4},
               {"name":"a","ph":"X","ts":20,"dur":30},
               {"name":"m","ph":"i","ts":60}"#,
        );
        let (stats, wall) = phase_breakdown(&t).unwrap();
        assert_eq!(stats.len(), 2);
        assert_eq!(stats[0].name, "a"); // 40 us total, sorted first
        assert_eq!(stats[0].count, 2);
        assert_eq!(stats[0].total_us, 40.0);
        assert_eq!(stats[0].max_us, 30.0);
        assert_eq!(stats[0].mean_us(), 20.0);
        assert_eq!(stats[1].total_us, 4.0);
        assert_eq!(wall, 60.0); // 0 .. max(ts, ts+dur) = 60
    }

    #[test]
    fn balanced_be_pairs_accepted_unbalanced_rejected() {
        let ok = trace(
            r#"{"name":"p","ph":"B","ts":0},
               {"name":"p","ph":"E","ts":5},
               {"name":"q","ph":"X","ts":1,"dur":2}"#,
        );
        assert!(phase_breakdown(&ok).is_ok());
        let bad = trace(r#"{"name":"p","ph":"B","ts":0}"#);
        let err = phase_breakdown(&bad).unwrap_err().to_string();
        assert!(err.contains("unbalanced"), "{err}");
    }

    #[test]
    fn x_without_dur_rejected() {
        let bad = trace(r#"{"name":"p","ph":"X","ts":0}"#);
        let err = format!("{:#}", phase_breakdown(&bad).unwrap_err());
        assert!(err.contains("without dur"), "{err}");
    }

    #[test]
    fn missing_trace_events_rejected() {
        let bad = Json::parse("{}").unwrap();
        assert!(phase_breakdown(&bad).is_err());
    }

    #[test]
    fn quantizer_health_renders_rates() {
        let prom = "\
quant_tensors_total{quantizer=\"ptq\"} 10
quant_values_total{quantizer=\"ptq\"} 1000
quant_clipped_total{quantizer=\"ptq\"} 15
quant_zero_codes_total{quantizer=\"ptq\"} 100
quant_sr_variance{quantizer=\"ptq\"} 0.0625
";
        let map = parse_prometheus(prom);
        let table = render_quantizer_health(&map);
        assert!(table.contains("ptq"), "{table}");
        assert!(table.contains("1.500"), "clip% missing: {table}");
        assert!(table.contains("10.000"), "zero% missing: {table}");
        assert!(table.contains("0.06250"), "var missing: {table}");
    }

    #[test]
    fn run_report_errors_on_missing_artifacts() {
        let dir = std::env::temp_dir().join(format!("sq_report_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        assert!(render_run_report(&dir).is_err(), "no trace.json");
        std::fs::write(dir.join("trace.json"), "not json").unwrap();
        assert!(render_run_report(&dir).is_err(), "unparseable trace.json");
        std::fs::write(
            dir.join("trace.json"),
            r#"{"traceEvents":[{"name":"a","ph":"X","ts":0,"dur":5}]}"#,
        )
        .unwrap();
        assert!(render_run_report(&dir).is_err(), "no metrics.prom");
        std::fs::write(dir.join("metrics.prom"), "train_steps_total 3\n").unwrap();
        let rep = render_run_report(&dir).unwrap();
        assert!(rep.contains("Per-phase time breakdown"));
        assert!(rep.contains("Quantizer health"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
