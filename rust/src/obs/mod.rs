//! Observability: metrics registry, hierarchical spans, quantizer
//! telemetry, and the `trace-report` renderer.
//!
//! The whole layer hangs off one global [`enabled`] flag (default on).
//! When disabled, every instrumentation site reduces to a relaxed
//! atomic load — no clock reads, no allocation, no locks — which is
//! what lets it stay on by default in every experiment binary (see
//! `benches/obs_overhead.rs` for the measured budget).
//!
//! Naming conventions (see DESIGN.md "Observability"):
//! - metrics: `snake_case`, counters end in `_total`, durations in
//!   `_seconds`; labels via [`registry::labeled`]
//!   (`executor_dispatch_total{backend="native",step="train"}`).
//! - spans: `area/phase` (`train/step`, `exec/train`, `dp/allreduce_quant`,
//!   and the ring all-reduce phases `ring/{step,worker_grad,quantize,
//!   reduce_scatter,all_gather}` from the threaded data-parallel engine).

pub mod quant;
pub mod registry;
pub mod report;
pub mod span;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;

pub use registry::{Counter, Gauge, HistogramMetric, MetricsRegistry};
pub use span::{instant, span, span_cat, SpanGuard};

static ENABLED: AtomicBool = AtomicBool::new(true);

/// Whether instrumentation is live. Checked on every hot-path site.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Toggle the whole observability layer (process-global).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// The process-global metrics registry every instrumentation site
/// registers into; exported per-run as `metrics.prom` / `metrics.jsonl`.
pub fn metrics() -> &'static MetricsRegistry {
    static REG: OnceLock<MetricsRegistry> = OnceLock::new();
    REG.get_or_init(MetricsRegistry::new)
}

/// Structured event: stderr line for the operator plus an instant event
/// in the trace stream (replaces ad-hoc `eprintln!` in the hot paths).
pub fn event(name: &str, fields: &[(&str, String)]) {
    if !enabled() {
        return;
    }
    let mut line = format!("[obs] {name}");
    for (k, v) in fields {
        line.push_str(&format!(" {k}={v}"));
    }
    eprintln!("{line}");
    span::instant(name, fields);
}

#[cfg(test)]
pub mod testutil {
    use std::sync::{Mutex, MutexGuard, OnceLock};

    /// Serialize tests that toggle the global enabled flag or assert on
    /// global sinks; a panicked holder must not wedge the rest.
    pub fn serial() -> MutexGuard<'static, ()> {
        static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
        LOCK.get_or_init(|| Mutex::new(()))
            .lock()
            .unwrap_or_else(|e| e.into_inner())
    }
}
