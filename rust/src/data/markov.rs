//! "markov": synthetic language corpus (IWSLT14 stand-in, paper §5.4).
//!
//! Token sequences from a sparse first-order Markov chain whose rows mix
//! a few high-probability transitions (learned early — the analogue of
//! easy/frequent tokens) with a long uniform tail (persistently hard).
//! The LM batch is (x, y) with y = x shifted left by one, matching the
//! transformer artifact's ABI.

use super::{Batch, Dataset};
use crate::runtime::HostTensor;
use crate::util::rng::Pcg32;

#[derive(Clone, Debug)]
pub struct MarkovConfig {
    pub vocab: usize,
    pub seq: usize,
    pub batch: usize,
    /// Number of dominant next-tokens per state.
    pub branch: usize,
    /// Probability mass on the dominant transitions (rest is uniform).
    pub peak_mass: f32,
    pub seed: u64,
}

impl Default for MarkovConfig {
    fn default() -> Self {
        Self {
            vocab: 256,
            seq: 64,
            batch: 16,
            branch: 4,
            peak_mass: 0.9,
            seed: 99,
        }
    }
}

pub struct Markov {
    cfg: MarkovConfig,
    /// succ[s] — the `branch` dominant successors of state s.
    succ: Vec<Vec<u32>>,
}

impl Markov {
    pub fn new(cfg: MarkovConfig) -> Self {
        let mut rng = Pcg32::new(cfg.seed, 31);
        let succ = (0..cfg.vocab)
            .map(|_| {
                (0..cfg.branch)
                    .map(|_| rng.below(cfg.vocab as u32))
                    .collect()
            })
            .collect();
        Self { cfg, succ }
    }

    pub fn config(&self) -> &MarkovConfig {
        &self.cfg
    }

    /// Per-token optimal cross-entropy of the chain (the loss floor a
    /// perfect model converges to) in nats.
    pub fn entropy_floor(&self) -> f64 {
        let v = self.cfg.vocab as f64;
        let b = self.cfg.branch as f64;
        let p_peak = f64::from(self.cfg.peak_mass) / b;
        let p_tail = (1.0 - f64::from(self.cfg.peak_mass)) / v;
        // each dominant successor also receives the tail mass
        let peak = p_peak + p_tail;
        -(b * peak * peak.ln() + (v - b) * p_tail * p_tail.ln())
    }

    fn next(&self, s: u32, rng: &mut Pcg32) -> u32 {
        if rng.uniform() < self.cfg.peak_mass {
            let k = rng.below(self.cfg.branch as u32) as usize;
            self.succ[s as usize][k]
        } else {
            rng.below(self.cfg.vocab as u32)
        }
    }

    fn gen(&self, stream: u64, idx: u64) -> Batch {
        let mut rng = Pcg32::new(self.cfg.seed ^ (stream << 21), idx + 1);
        let n = self.cfg.batch;
        let t = self.cfg.seq;
        let mut x = Vec::with_capacity(n * t);
        let mut y = Vec::with_capacity(n * t);
        for _ in 0..n {
            let mut s = rng.below(self.cfg.vocab as u32);
            // x_t is the context token, y_t the next token
            for _ in 0..t {
                x.push(s as i32);
                s = self.next(s, &mut rng);
                y.push(s as i32);
            }
        }
        Batch {
            x: HostTensor::I32(x),
            y: HostTensor::I32(y),
        }
    }
}

impl Dataset for Markov {
    fn batch(&self, step: u64) -> Batch {
        self.gen(0, step)
    }

    fn eval_batch(&self, idx: u64) -> Batch {
        self.gen(1, idx)
    }

    fn batch_size(&self) -> usize {
        self.cfg.batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ds() -> Markov {
        Markov::new(MarkovConfig::default())
    }

    fn tokens(t: &HostTensor) -> &[i32] {
        match t {
            HostTensor::I32(v) => v,
            _ => panic!("expected i32"),
        }
    }

    #[test]
    fn deterministic_and_shifted() {
        let d = ds();
        let a = d.batch(1);
        let b = d.batch(1);
        assert_eq!(tokens(&a.x), tokens(&b.x));
        // y is x shifted: y[t] == x[t+1] within a row
        let x = tokens(&a.x);
        let y = tokens(&a.y);
        for row in 0..16 {
            for t in 0..63 {
                assert_eq!(y[row * 64 + t], x[row * 64 + t + 1]);
            }
        }
    }

    #[test]
    fn tokens_in_vocab() {
        let d = ds();
        let b = d.batch(0);
        assert!(tokens(&b.x).iter().all(|&t| (0..256).contains(&t)));
        assert!(tokens(&b.y).iter().all(|&t| (0..256).contains(&t)));
        assert_eq!(b.x.len(), 16 * 64);
    }

    #[test]
    fn dominant_transitions_dominate() {
        let d = ds();
        let mut hits = 0u64;
        let mut total = 0u64;
        for step in 0..20 {
            let b = d.batch(step);
            let x = tokens(&b.x);
            let y = tokens(&b.y);
            for i in 0..x.len() {
                total += 1;
                if d.succ[x[i] as usize].contains(&(y[i] as u32)) {
                    hits += 1;
                }
            }
        }
        let frac = hits as f64 / total as f64;
        assert!(frac > 0.85, "dominant fraction {frac}");
    }

    #[test]
    fn entropy_floor_sane() {
        let d = ds();
        let h = d.entropy_floor();
        // must be far below uniform ln(256) ~ 5.55 and above ln(branch)
        assert!(h < 3.5, "{h}");
        assert!(h > (4f64).ln() * 0.5, "{h}");
    }

    #[test]
    fn eval_differs_from_train() {
        let d = ds();
        assert_ne!(tokens(&d.batch(2).x), tokens(&d.eval_batch(2).x));
    }
}
