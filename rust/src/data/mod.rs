//! Synthetic data substrates (S9, DESIGN.md §4 substitutions).
//!
//! The paper trains on CIFAR10/ImageNet/IWSLT14; this repo's CPU-scale
//! stand-ins are generated here, engineered to reproduce the *gradient
//! structure* the paper's analysis hinges on: as training accuracy rises,
//! most samples' gradient rows collapse toward zero while a few hard
//! outliers stay large — exactly the row-range skew that separates
//! PTQ / PSQ / BHQ.

pub mod markov;
pub mod synthimg;

use crate::runtime::HostTensor;

/// One training batch in ABI form.
#[derive(Clone, Debug)]
pub struct Batch {
    pub x: HostTensor,
    pub y: HostTensor,
}

/// A deterministic, infinitely iterable synthetic dataset.
///
/// `Send + Sync` (implementations hold only precomputed tables): the
/// data-parallel pool reads batches from many threads, keyed purely by
/// the step index.
pub trait Dataset: Send + Sync {
    /// Deterministic batch for a global step index (same step -> same
    /// batch, across runs and workers).
    fn batch(&self, step: u64) -> Batch;

    /// Held-out batch stream disjoint from training (`batch`) draws.
    fn eval_batch(&self, idx: u64) -> Batch;

    fn batch_size(&self) -> usize;
}
