//! "synthimg": synthetic image classification (CIFAR10/ImageNet stand-in).
//!
//! Each class c has a fixed random template T_c (drawn once from the
//! dataset seed). A sample is T_c + noise, with three structural knobs
//! that make the task behave like the paper's workloads:
//!
//!  * `noise` controls difficulty (how fast training accuracy saturates);
//!  * `hard_frac` of samples are "hard": they blend two class templates
//!    50/50 but keep one label — these become the persistent gradient
//!    outliers that PSQ/BHQ exploit (paper §4.1/Fig 4);
//!  * inputs are standardized to ~N(0,1) per pixel, matching the
//!    normalized-image convention the models were traced with.

use super::{Batch, Dataset};
use crate::runtime::HostTensor;
use crate::util::rng::Pcg32;

#[derive(Clone, Debug)]
pub struct SynthImgConfig {
    pub classes: usize,
    /// Flattened input element count per sample (H*W*C).
    pub dims: Vec<usize>,
    pub batch: usize,
    pub noise: f32,
    pub hard_frac: f32,
    pub seed: u64,
}

impl Default for SynthImgConfig {
    fn default() -> Self {
        Self {
            classes: 10,
            dims: vec![16, 16, 3],
            batch: 32,
            noise: 0.6,
            hard_frac: 0.08,
            seed: 1234,
        }
    }
}

pub struct SynthImg {
    cfg: SynthImgConfig,
    /// templates[c] — fixed per dataset seed.
    templates: Vec<Vec<f32>>,
    numel: usize,
}

impl SynthImg {
    pub fn new(cfg: SynthImgConfig) -> Self {
        let numel: usize = cfg.dims.iter().product();
        let mut rng = Pcg32::new(cfg.seed, 77);
        let templates = (0..cfg.classes)
            .map(|_| (0..numel).map(|_| rng.normal()).collect())
            .collect();
        Self {
            cfg,
            templates,
            numel,
        }
    }

    pub fn config(&self) -> &SynthImgConfig {
        &self.cfg
    }

    fn gen(&self, stream: u64, idx: u64) -> Batch {
        let mut rng = Pcg32::new(self.cfg.seed ^ (stream << 17), idx + 1);
        let n = self.cfg.batch;
        let mut x = Vec::with_capacity(n * self.numel);
        let mut y = Vec::with_capacity(n);
        let norm = 1.0 / (1.0 + self.cfg.noise * self.cfg.noise).sqrt();
        for _ in 0..n {
            let c = rng.below(self.cfg.classes as u32) as usize;
            y.push(c as i32);
            let hard = rng.uniform() < self.cfg.hard_frac;
            let c2 = if hard {
                let mut o = rng.below(self.cfg.classes as u32) as usize;
                if o == c {
                    o = (o + 1) % self.cfg.classes;
                }
                Some(o)
            } else {
                None
            };
            for j in 0..self.numel {
                let mut t = self.templates[c][j];
                if let Some(o) = c2 {
                    t = 0.5 * t + 0.5 * self.templates[o][j];
                }
                x.push((t + self.cfg.noise * rng.normal()) * norm);
            }
        }
        Batch {
            x: HostTensor::F32(x),
            y: HostTensor::I32(y),
        }
    }
}

impl Dataset for SynthImg {
    fn batch(&self, step: u64) -> Batch {
        self.gen(0, step)
    }

    fn eval_batch(&self, idx: u64) -> Batch {
        self.gen(1, idx)
    }

    fn batch_size(&self) -> usize {
        self.cfg.batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ds() -> SynthImg {
        SynthImg::new(SynthImgConfig::default())
    }

    #[test]
    fn deterministic_by_step() {
        let d = ds();
        let a = d.batch(5);
        let b = d.batch(5);
        assert_eq!(a.x.as_f32().unwrap(), b.x.as_f32().unwrap());
        let c = d.batch(6);
        assert_ne!(a.x.as_f32().unwrap(), c.x.as_f32().unwrap());
    }

    #[test]
    fn eval_stream_disjoint_from_train() {
        let d = ds();
        assert_ne!(
            d.batch(3).x.as_f32().unwrap(),
            d.eval_batch(3).x.as_f32().unwrap()
        );
    }

    #[test]
    fn shapes_and_labels_valid() {
        let d = ds();
        let b = d.batch(0);
        assert_eq!(b.x.len(), 32 * 16 * 16 * 3);
        let y = match &b.y {
            HostTensor::I32(v) => v.clone(),
            _ => panic!("labels must be i32"),
        };
        assert_eq!(y.len(), 32);
        assert!(y.iter().all(|&c| (0..10).contains(&c)));
    }

    #[test]
    fn inputs_roughly_standardized() {
        let d = ds();
        let mut s1 = 0.0f64;
        let mut s2 = 0.0f64;
        let mut n = 0u64;
        for step in 0..8 {
            for &v in d.batch(step).x.as_f32().unwrap() {
                s1 += f64::from(v);
                s2 += f64::from(v) * f64::from(v);
                n += 1;
            }
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.1, "mean {mean}");
        assert!((var - 1.0).abs() < 0.25, "var {var}");
    }

    #[test]
    fn different_dataset_seeds_differ() {
        let a = SynthImg::new(SynthImgConfig {
            seed: 1,
            ..Default::default()
        });
        let b = SynthImg::new(SynthImgConfig {
            seed: 2,
            ..Default::default()
        });
        assert_ne!(
            a.batch(0).x.as_f32().unwrap(),
            b.batch(0).x.as_f32().unwrap()
        );
    }

    #[test]
    fn class_templates_make_task_learnable() {
        // nearest-template classification should beat chance by a lot —
        // sanity that the generative structure carries label signal.
        let d = ds();
        let b = d.batch(0);
        let x = b.x.as_f32().unwrap();
        let y = match &b.y {
            HostTensor::I32(v) => v,
            _ => unreachable!(),
        };
        let numel = 16 * 16 * 3;
        let mut correct = 0;
        for i in 0..32 {
            let xi = &x[i * numel..(i + 1) * numel];
            let mut best = (f32::INFINITY, 0usize);
            for (c, t) in d.templates.iter().enumerate() {
                let dist: f32 = xi
                    .iter()
                    .zip(t)
                    .map(|(&a, &b)| {
                        let norm = (1.0 + 0.6f32 * 0.6) .sqrt();
                        let d = a * norm - b;
                        d * d
                    })
                    .sum();
                if dist < best.0 {
                    best = (dist, c);
                }
            }
            if best.1 == y[i] as usize {
                correct += 1;
            }
        }
        assert!(correct >= 20, "nearest-template acc {correct}/32");
    }
}
