//! Welford's online mean/variance — numerically stable single-pass
//! estimators, scalar and vectorized.
//!
//! The vector form is the workhorse of the Fig-3/Fig-5 experiments: the
//! probe artifact returns the flat parameter gradient, the coordinator
//! feeds K seeds worth of gradients in, and `total_variance()` yields
//! Var[grad] = E||g - Eg||^2 — the paper's Definition in §3.2 (sum of
//! per-coordinate variances).

/// Scalar Welford accumulator.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance (σ², divides by n).
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Unbiased sample variance (divides by n-1).
    pub fn sample_variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Standard error of the mean.
    pub fn sem(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            (self.sample_variance() / self.n as f64).sqrt()
        }
    }

    /// Merge two accumulators (parallel reduction; Chan et al.).
    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = (self.n + other.n) as f64;
        let d = other.mean - self.mean;
        self.m2 += other.m2 + d * d * self.n as f64 * other.n as f64 / n;
        self.mean += d * other.n as f64 / n;
        self.n += other.n;
    }
}

/// Per-coordinate Welford over f32 vectors of fixed length.
#[derive(Clone, Debug)]
pub struct VectorWelford {
    n: u64,
    mean: Vec<f64>,
    m2: Vec<f64>,
}

impl VectorWelford {
    pub fn new(len: usize) -> Self {
        Self {
            n: 0,
            mean: vec![0.0; len],
            m2: vec![0.0; len],
        }
    }

    pub fn len(&self) -> usize {
        self.mean.len()
    }

    pub fn is_empty(&self) -> bool {
        self.mean.is_empty()
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn push(&mut self, xs: &[f32]) {
        assert_eq!(xs.len(), self.mean.len(), "dimension mismatch");
        self.n += 1;
        let inv_n = 1.0 / self.n as f64;
        for ((m, s), &x) in self.mean.iter_mut().zip(self.m2.iter_mut()).zip(xs) {
            let x = f64::from(x);
            let d = x - *m;
            *m += d * inv_n;
            *s += d * (x - *m);
        }
    }

    pub fn mean(&self) -> &[f64] {
        &self.mean
    }

    /// Var[X] per the paper's §3.2 definition: sum over coordinates of
    /// the per-coordinate (sample) variance.
    pub fn total_variance(&self) -> f64 {
        if self.n < 2 {
            return 0.0;
        }
        self.m2.iter().sum::<f64>() / (self.n - 1) as f64
    }

    /// Per-coordinate sample variances.
    pub fn coordinate_variances(&self) -> Vec<f64> {
        if self.n < 2 {
            return vec![0.0; self.m2.len()];
        }
        let d = (self.n - 1) as f64;
        self.m2.iter().map(|&m| m / d).collect()
    }

    /// ||E[X]||^2 — used to normalize variance into a relative scale.
    pub fn mean_sq_norm(&self) -> f64 {
        self.mean.iter().map(|&m| m * m).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn matches_two_pass_variance() {
        let mut rng = Pcg32::new(1, 0);
        let xs: Vec<f64> = (0..5000).map(|_| f64::from(rng.normal()) * 3.0 + 1.0).collect();
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|&x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!((w.mean() - mean).abs() < 1e-10);
        assert!((w.variance() - var).abs() < 1e-9);
    }

    #[test]
    fn merge_equals_sequential() {
        let mut rng = Pcg32::new(2, 0);
        let xs: Vec<f64> = (0..1000).map(|_| f64::from(rng.normal())).collect();
        let mut all = Welford::new();
        let (mut a, mut b) = (Welford::new(), Welford::new());
        for (i, &x) in xs.iter().enumerate() {
            all.push(x);
            if i % 2 == 0 {
                a.push(x)
            } else {
                b.push(x)
            }
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert!((a.mean() - all.mean()).abs() < 1e-10);
        assert!((a.variance() - all.variance()).abs() < 1e-9);
    }

    #[test]
    fn merge_with_empty() {
        let mut a = Welford::new();
        a.push(1.0);
        a.push(3.0);
        let b = Welford::new();
        let mut c = a.clone();
        c.merge(&b);
        assert_eq!(c.mean(), a.mean());
        let mut d = Welford::new();
        d.merge(&a);
        assert_eq!(d.mean(), a.mean());
    }

    #[test]
    fn vector_welford_total_variance() {
        // X ~ N(mu, diag(sigma^2)): total variance ~ sum sigma_i^2
        let mut rng = Pcg32::new(3, 0);
        let sigmas = [1.0f32, 2.0, 0.5];
        let mut vw = VectorWelford::new(3);
        for _ in 0..20_000 {
            let x: Vec<f32> = sigmas.iter().map(|&s| rng.normal() * s).collect();
            vw.push(&x);
        }
        let want: f64 = sigmas.iter().map(|&s| f64::from(s) * f64::from(s)).sum();
        let got = vw.total_variance();
        assert!((got - want).abs() / want < 0.05, "{got} vs {want}");
    }

    #[test]
    fn deterministic_vector_is_zero_variance() {
        let mut vw = VectorWelford::new(4);
        for _ in 0..10 {
            vw.push(&[1.0, 2.0, 3.0, 4.0]);
        }
        assert_eq!(vw.total_variance(), 0.0);
        assert_eq!(vw.mean(), &[1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn sem_shrinks_with_n() {
        let mut rng = Pcg32::new(4, 0);
        let mut w = Welford::new();
        for _ in 0..100 {
            w.push(f64::from(rng.normal()));
        }
        let sem100 = w.sem();
        for _ in 0..9900 {
            w.push(f64::from(rng.normal()));
        }
        assert!(w.sem() < sem100 / 5.0);
    }
}
