//! Gradient-variance probes — the measurement machinery behind Fig 3(a),
//! Fig 5(a) and the Thm-1/Eq-10 validation experiments.
//!
//! The paper decomposes Var[FQT grad] = Var[QAT grad] + quantization
//! variance (Theorem 2 / law of total variance). We estimate both terms
//! empirically with the `probe` artifacts:
//!
//!  * **quantization variance** E[Var[ĝ | B]]: fix a batch, run the FQT
//!    probe with K different seeds, Welford over the flat gradients;
//!  * **QAT (subsampling) variance** Var[∇]: run the QAT probe (exact
//!    deterministic backward of the quantized model) over K different
//!    batches, Welford across batches.

use anyhow::Result;

use super::welford::VectorWelford;
use crate::runtime::{Executor, HostTensor};

/// One measured point of the Fig-3(a)/Fig-5(a) curves.
#[derive(Clone, Debug)]
pub struct VarianceReport {
    pub variant: String,
    pub bits: f32,
    /// E[Var[grad | batch]] — variance injected by gradient quantization.
    pub quant_variance: f64,
    /// ||E[grad | batch]||^2 — scale reference for relative variance.
    pub mean_sq_norm: f64,
    pub seeds: usize,
}

impl VarianceReport {
    /// Quantization variance relative to the squared gradient norm.
    pub fn relative(&self) -> f64 {
        self.quant_variance / self.mean_sq_norm.max(1e-30)
    }
}

/// Probe driver over a `probe` artifact:
/// inputs (params, x, y, seed, bits) -> (loss, flat_grad).
pub struct GradVarianceProbe<'a> {
    pub exec: &'a Executor,
}

impl<'a> GradVarianceProbe<'a> {
    pub fn new(exec: &'a Executor) -> Self {
        Self { exec }
    }

    fn run_once(
        &self,
        params: &[f32],
        x: &HostTensor,
        y: &HostTensor,
        seed: f32,
        bits: f32,
    ) -> Result<Vec<f32>> {
        let inputs = [
            HostTensor::F32(params.to_vec()),
            x.clone(),
            y.clone(),
            HostTensor::F32(vec![seed]),
            HostTensor::F32(vec![bits]),
        ];
        let out = self.exec.run(&inputs)?;
        // outputs: (loss, grad)
        out.into_iter()
            .nth(1)
            .expect("probe returns (loss, grad)")
            .into_f32()
    }

    /// Quantization variance on a fixed batch across `seeds` SR draws.
    pub fn quantization_variance(
        &self,
        params: &[f32],
        x: &HostTensor,
        y: &HostTensor,
        bits: f32,
        seeds: usize,
        seed0: u32,
    ) -> Result<VarianceReport> {
        let mut vw = VectorWelford::new(self.exec.meta.n_params);
        for k in 0..seeds {
            let g = self.run_once(params, x, y, (seed0 + k as u32) as f32, bits)?;
            vw.push(&g);
        }
        Ok(VarianceReport {
            variant: self.exec.meta.variant.clone(),
            bits,
            quant_variance: vw.total_variance(),
            mean_sq_norm: vw.mean_sq_norm(),
            seeds,
        })
    }

    /// Mean gradient over `seeds` draws on a fixed batch (Thm-1 check:
    /// should converge to the QAT gradient). Returns the per-coordinate
    /// Monte-Carlo variances alongside, so callers can form exact
    /// per-coordinate z-scores.
    pub fn mean_gradient(
        &self,
        params: &[f32],
        x: &HostTensor,
        y: &HostTensor,
        bits: f32,
        seeds: usize,
        seed0: u32,
    ) -> Result<(Vec<f64>, Vec<f64>)> {
        let mut vw = VectorWelford::new(self.exec.meta.n_params);
        for k in 0..seeds {
            let g = self.run_once(params, x, y, (seed0 + k as u32) as f32, bits)?;
            vw.push(&g);
        }
        Ok((vw.mean().to_vec(), vw.coordinate_variances()))
    }

    /// Subsampling variance: one probe call per batch (deterministic QAT
    /// probes ignore the seed), Welford across batches.
    pub fn batch_variance(
        &self,
        params: &[f32],
        batches: &[(HostTensor, HostTensor)],
        bits: f32,
    ) -> Result<VarianceReport> {
        let mut vw = VectorWelford::new(self.exec.meta.n_params);
        for (i, (x, y)) in batches.iter().enumerate() {
            let g = self.run_once(params, x, y, i as f32, bits)?;
            vw.push(&g);
        }
        Ok(VarianceReport {
            variant: self.exec.meta.variant.clone(),
            bits,
            quant_variance: vw.total_variance(),
            mean_sq_norm: vw.mean_sq_norm(),
            seeds: batches.len(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relative_variance_guards_zero_norm() {
        let r = VarianceReport {
            variant: "ptq".into(),
            bits: 4.0,
            quant_variance: 1.0,
            mean_sq_norm: 0.0,
            seeds: 8,
        };
        assert!(r.relative().is_finite());
    }
}
