//! Histograms for the Fig-4 experiment: quantized-code utilization and
//! bin-size distributions, plus generic value histograms for gradients.

/// Fixed-range histogram over f32 values.
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
    pub underflow: u64,
    pub overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(bins > 0 && hi > lo, "bad histogram spec");
        Self {
            lo,
            hi,
            counts: vec![0; bins],
            underflow: 0,
            overflow: 0,
        }
    }

    /// Build with range from the data itself.
    pub fn from_values(values: &[f32], bins: usize) -> Self {
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for &v in values {
            lo = lo.min(f64::from(v));
            hi = hi.max(f64::from(v));
        }
        if !lo.is_finite() || lo == hi {
            lo = 0.0;
            hi = 1.0;
        }
        let mut h = Self::new(lo, hi + (hi - lo) * 1e-9, bins);
        for &v in values {
            h.push(f64::from(v));
        }
        h
    }

    pub fn push(&mut self, v: f64) {
        if v < self.lo {
            self.underflow += 1;
        } else if v >= self.hi {
            self.overflow += 1;
        } else {
            let idx = ((v - self.lo) / (self.hi - self.lo) * self.counts.len() as f64)
                as usize;
            let idx = idx.min(self.counts.len() - 1);
            self.counts[idx] += 1;
        }
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum::<u64>() + self.underflow + self.overflow
    }

    /// Fraction of bins with at least one sample — the paper's Fig-4
    /// "utilization of quantization bins" notion.
    pub fn utilization(&self) -> f64 {
        let used = self.counts.iter().filter(|&&c| c > 0).count();
        used as f64 / self.counts.len() as f64
    }

    /// Shannon entropy of the bin distribution in bits (higher = flatter
    /// histogram = better code utilization; PTQ's zero-spike scores low).
    pub fn entropy_bits(&self) -> f64 {
        let n: u64 = self.counts.iter().sum();
        if n == 0 {
            return 0.0;
        }
        let n = n as f64;
        -self
            .counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| {
                let p = c as f64 / n;
                p * p.log2()
            })
            .sum::<f64>()
    }

    /// CSV rows "bin_center,count".
    pub fn to_csv(&self) -> String {
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        let mut out = String::from("bin_center,count\n");
        for (i, &c) in self.counts.iter().enumerate() {
            out.push_str(&format!("{},{}\n", self.lo + (i as f64 + 0.5) * w, c));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_binning() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for v in [0.5, 1.5, 1.7, 9.9, -1.0, 10.0, 11.0] {
            h.push(v);
        }
        assert_eq!(h.counts[0], 1);
        assert_eq!(h.counts[1], 2);
        assert_eq!(h.counts[9], 1);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 2);
        assert_eq!(h.total(), 7);
    }

    #[test]
    fn from_values_covers_all() {
        let vals: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let h = Histogram::from_values(&vals, 10);
        assert_eq!(h.underflow + h.overflow, 0);
        assert_eq!(h.total(), 100);
        assert!((h.utilization() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn entropy_spike_vs_flat() {
        // all mass in one bin -> entropy 0; uniform -> log2(bins)
        let spike = Histogram::from_values(&vec![0.5f32; 1000], 16);
        assert!(spike.entropy_bits() < 1e-9);
        let flat_vals: Vec<f32> = (0..1600).map(|i| (i % 16) as f32).collect();
        let flat = Histogram::from_values(&flat_vals, 16);
        assert!((flat.entropy_bits() - 4.0).abs() < 0.01);
    }

    #[test]
    fn constant_values_dont_panic() {
        let h = Histogram::from_values(&[2.0f32; 5], 4);
        assert_eq!(h.total(), 5);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let h = Histogram::from_values(&[0.0, 1.0, 2.0, 3.0], 4);
        let csv = h.to_csv();
        assert!(csv.starts_with("bin_center,count\n"));
        assert_eq!(csv.lines().count(), 5);
    }
}
