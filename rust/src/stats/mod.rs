//! Statistics engine (S10): streaming variance estimation, histograms,
//! and the gradient-variance decomposition experiments of Fig 3 / Fig 5.

pub mod histogram;
pub mod variance;
pub mod welford;

pub use histogram::Histogram;
pub use variance::{GradVarianceProbe, VarianceReport};
pub use welford::{VectorWelford, Welford};
