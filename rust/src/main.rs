//! `statquant` — CLI launcher for the StatQuant training framework.
//!
//! Commands:
//!   train [config.toml] [--set k=v ...]      one training run
//!   eval  --model M [--ckpt meta.json]       evaluate a checkpoint/init
//!   probe --model M --variant Q [--bits ...] gradient-variance probe
//!   exp <name> [flags]                       regenerate a paper table/figure
//!   gen-artifacts [--artifacts DIR]          write the native MLP artifacts
//!   list                                     show available artifacts
//!   trace-report <run-dir>                   render obs artifacts as markdown
//!   bench-check [names...] [--min g=thr ...] gate CI on bench snapshots
//!
//! Python never runs here: either `make artifacts` (AOT-lowered HLO, run
//! under `--features pjrt`) or `statquant gen-artifacts` (native backend)
//! must have populated the artifacts directory beforehand.

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use statquant::config::TrainConfig;
use statquant::coordinator::{Checkpoint, Trainer};
use statquant::experiments;
use statquant::metrics::fmt_sig;
use statquant::runtime::{MlpSpec, Registry, Runtime, StepKind};
use statquant::stats::GradVarianceProbe;
use statquant::util::cli::Args;
use statquant::util::json::Json;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> &'static str {
    "usage: statquant <train|eval|probe|exp|gen-artifacts|list|trace-report|bench-check> [options]\n\
     \n\
     train [config.toml] [--artifacts DIR] [--set key=value ...]\n\
     \x20     [--compute simulate|int8]                  backward GEMM arithmetic\n\
     \x20     [--dp-threads N] [--dp-mode dense|ring]   data-parallel engine\n\
     \x20     (runs when train.workers > 1; see train.allreduce_bits/_quant)\n\
     eval  --model M [--artifacts DIR] [--ckpt ckpt_xxx.json] [--batches N]\n\
     probe --model M --variant Q [--bits 4,5,6] [--seeds K] [--warm N]\n\
     exp   <fig3a|fig3bc|fig4|fig5|table1|table2|thm1|ablate-*> [flags]\n\
     gen-artifacts [--artifacts DIR]\n\
     list  [--artifacts DIR]\n\
     trace-report <run-dir>   per-phase time breakdown + quantizer health\n\
     \x20                      from trace.json / metrics.prom / log.jsonl\n\
     bench-check [names...] [--dir results/bench] [--min gauge=threshold ...]\n\
     \x20                      [--max gauge=ceiling ...]\n\
     \x20                      fail unless every BENCH_<name>.json exists, parses,\n\
     \x20                      records gauges, and meets the --min/--max gates\n"
}

fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    let artifacts = args.flag("artifacts").unwrap_or("artifacts").to_string();
    match args.command.as_str() {
        "" | "help" | "--help" => {
            print!("{}", usage());
            Ok(())
        }
        "list" => {
            args.check_unknown()?;
            let reg = Registry::open(&artifacts)?;
            let mut keys = reg.keys();
            keys.sort();
            for k in keys {
                println!("{k}");
            }
            Ok(())
        }
        "gen-artifacts" => {
            args.check_unknown()?;
            let spec = MlpSpec::default();
            statquant::runtime::native::write_artifacts(Path::new(&artifacts), &spec)?;
            println!(
                "[gen-artifacts] wrote mlp artifacts ({} params) -> {artifacts}",
                spec.n_params()
            );
            Ok(())
        }
        "train" => cmd_train(&args, &artifacts),
        "eval" => cmd_eval(&args, &artifacts),
        "probe" => cmd_probe(&args, &artifacts),
        "bench-check" => cmd_bench_check(&args),
        "trace-report" => {
            let dir = args
                .positional
                .first()
                .context("trace-report requires a run directory")?
                .clone();
            args.check_unknown()?;
            print!(
                "{}",
                statquant::obs::report::render_run_report(Path::new(&dir))?
            );
            Ok(())
        }
        "exp" => {
            let name = args
                .positional
                .first()
                .context("exp requires a name (e.g. `statquant exp fig3a`)")?
                .clone();
            let rt = Runtime::cpu()?;
            let reg = Registry::open(&artifacts)?;
            experiments::run(&name, &rt, &reg, &args)
        }
        other => bail!("unknown command {other:?}\n{}", usage()),
    }
}

/// CI bench gate: every named `BENCH_<name>.json` snapshot must exist,
/// parse, and carry a non-empty `gauges` object; every `--min g=thr`
/// floor and `--max g=thr` ceiling must be met by the gauge `g` (exact
/// name, or every labeled series `g{...}`). Non-numeric gauge values
/// (the snapshot encodes non-finite floats as strings) fail the gate
/// rather than pass it.
fn cmd_bench_check(args: &Args) -> Result<()> {
    let dir = args.flag("dir").unwrap_or("results/bench").to_string();
    let mins: Vec<String> = args.flag_all("min").iter().map(|s| s.to_string()).collect();
    let maxes: Vec<String> = args.flag_all("max").iter().map(|s| s.to_string()).collect();
    let names: Vec<String> = if args.positional.is_empty() {
        vec!["train_step".into(), "quantizers".into()]
    } else {
        args.positional.clone()
    };
    args.check_unknown()?;

    let mut gauges: std::collections::BTreeMap<String, Json> = Default::default();
    for name in &names {
        let path = Path::new(&dir).join(format!("BENCH_{name}.json"));
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("bench snapshot missing: {}", path.display()))?;
        let json = Json::parse(&text)
            .map_err(|e| anyhow!("malformed {} at byte {}: {}", path.display(), e.pos, e.msg))?;
        let g = match json.get("gauges") {
            Some(Json::Obj(m)) if !m.is_empty() => m,
            _ => bail!(
                "{}: no gauges recorded (missing or empty `gauges` object)",
                path.display()
            ),
        };
        println!("[bench-check] {}: {} gauges", path.display(), g.len());
        for (k, v) in g {
            gauges.insert(k.clone(), v.clone());
        }
    }

    for (specs, flag, is_min) in [(&mins, "--min", true), (&maxes, "--max", false)] {
        for spec in specs {
            let (gname, thr) = spec
                .split_once('=')
                .with_context(|| format!("{flag} expects gauge=threshold, got {spec:?}"))?;
            let thr: f64 = thr
                .parse()
                .with_context(|| format!("{flag} {spec:?}: threshold is not a number"))?;
            let labeled_prefix = format!("{gname}{{");
            let matching: Vec<(&String, &Json)> = gauges
                .iter()
                .filter(|(k, _)| k.as_str() == gname || k.starts_with(&labeled_prefix))
                .collect();
            if matching.is_empty() {
                bail!("gauge {gname:?} not found in any checked bench snapshot");
            }
            for (k, v) in matching {
                let val = v.as_f64().with_context(|| {
                    format!(
                        "gauge {k} is non-numeric ({v:?}) — the bench recorded a non-finite value"
                    )
                })?;
                if is_min && val < thr {
                    bail!("gauge {k} = {val} is below the required minimum {thr}");
                }
                if !is_min && val > thr {
                    bail!("gauge {k} = {val} is above the allowed maximum {thr}");
                }
                let rel = if is_min { ">=" } else { "<=" };
                println!("[bench-check] {k} = {val:.3} {rel} {thr}");
            }
        }
    }
    println!(
        "[bench-check] ok: {} snapshot(s), {} gauge(s), {} gate(s)",
        names.len(),
        gauges.len(),
        mins.len() + maxes.len()
    );
    Ok(())
}

fn cmd_train(args: &Args, artifacts: &str) -> Result<()> {
    let mut cfg = match args.positional.first() {
        Some(path) => TrainConfig::from_toml_file(Path::new(path))?,
        None => TrainConfig::default(),
    };
    cfg.artifacts_dir = artifacts.to_string();
    for kv in args.flag_all("set") {
        cfg.set(kv)?;
    }
    // dp-engine sugar over --set train.dp_*
    if let Some(v) = args.flag_parse::<usize>("dp-threads")? {
        cfg.dp_threads = v;
    }
    if let Some(v) = args.flag("dp-mode") {
        cfg.dp_mode = v.to_string();
    }
    // sugar over --set train.compute
    if let Some(v) = args.flag("compute") {
        cfg.compute = v.to_string();
    }
    args.check_unknown()?;
    cfg.validate()?;

    let mut rt = Runtime::cpu()?;
    match statquant::runtime::ComputeMode::from_name(&cfg.compute) {
        Some(mode) => rt.set_compute(mode),
        None => bail!("unknown compute mode {:?}", cfg.compute), // unreachable post-validate
    }
    let reg = Registry::open(&cfg.artifacts_dir)?;
    if cfg.workers > 1 {
        println!(
            "[train] data-parallel {} on {}: {} workers x {} threads, {} reduce ({} @ {} bits)",
            cfg.variant,
            cfg.model,
            cfg.workers,
            cfg.dp_threads,
            cfg.dp_mode,
            cfg.allreduce_quant,
            cfg.allreduce_bits
        );
        let report = statquant::coordinator::train_data_parallel(&rt, &reg, cfg.clone())?;
        println!(
            "[train] done: {} steps in {:.1}s ({:.2} steps/s)\n\
             [train] train loss {:.4}, eval loss {:.4}, eval acc {:.4}{}\n\
             [train] run dir -> {}",
            report.steps,
            report.wall_seconds,
            report.steps_per_second,
            report.final_train_loss,
            report.final_eval_loss,
            report.final_eval_acc,
            match report.diverged_at_step {
                Some(s) => format!(" (DIVERGED at step {s})"),
                None => String::new(),
            },
            Path::new(&cfg.out_dir).join(cfg.run_name()).display()
        );
        return Ok(());
    }
    println!(
        "[train] {} on {} ({} steps, lr {}, {} bits{})",
        cfg.variant,
        cfg.model,
        cfg.steps,
        cfg.lr,
        cfg.bits,
        if cfg.compute == "int8" { ", int8 compute" } else { "" }
    );
    let mut tr = Trainer::new(&rt, &reg, cfg.clone())?;
    let report = tr.train()?;
    // final checkpoint
    let ck = Checkpoint {
        step: report.steps,
        params: tr.params.clone(),
        momentum: tr.momentum.clone(),
    };
    let out = Path::new(&cfg.out_dir).join(cfg.run_name());
    let meta = ck.save(&out)?;
    println!(
        "[train] done: {} steps in {:.1}s ({:.2} steps/s)\n\
         [train] train loss {:.4}, eval loss {:.4}, eval acc {:.4}{}\n\
         [train] checkpoint -> {}",
        report.steps,
        report.wall_seconds,
        report.steps_per_second,
        report.final_train_loss,
        report.final_eval_loss,
        report.final_eval_acc,
        match report.diverged_at_step {
            Some(s) => format!(" (DIVERGED at step {s})"),
            None => String::new(),
        },
        meta.display()
    );
    Ok(())
}

fn cmd_eval(args: &Args, artifacts: &str) -> Result<()> {
    let model = args.flag("model").context("--model required")?.to_string();
    let batches: u64 = args.flag_parse("batches")?.unwrap_or(16);
    let ckpt = args.flag("ckpt").map(String::from);
    args.check_unknown()?;

    let rt = Runtime::cpu()?;
    let reg = Registry::open(artifacts)?;
    let cfg = TrainConfig {
        model: model.clone(),
        variant: "qat".into(),
        artifacts_dir: artifacts.to_string(),
        ..TrainConfig::default()
    };
    let mut tr = Trainer::new(&rt, &reg, cfg)?;
    if let Some(p) = ckpt {
        let ck = Checkpoint::load(Path::new(&p))?;
        tr.params = ck.params;
        println!("[eval] loaded checkpoint at step {}", ck.step);
    }
    let (loss, acc) = tr.evaluate(batches)?;
    println!("[eval] {model}: loss {loss:.4}, acc {acc:.4} over {batches} batches");
    Ok(())
}

fn cmd_probe(args: &Args, artifacts: &str) -> Result<()> {
    let model = args.flag("model").context("--model required")?.to_string();
    let variant = args.flag("variant").unwrap_or("ptq").to_string();
    let seeds: usize = args.flag_parse("seeds")?.unwrap_or(12);
    let warm: u64 = args.flag_parse("warm")?.unwrap_or(50);
    let bits: Vec<f32> = args
        .flag("bits")
        .unwrap_or("4,5,6,7,8")
        .split(',')
        .map(|s| s.trim().parse().expect("bad --bits"))
        .collect();
    args.check_unknown()?;

    let rt = Runtime::cpu()?;
    let reg = Registry::open(artifacts)?;
    let cfg = TrainConfig {
        model: model.clone(),
        artifacts_dir: artifacts.to_string(),
        out_dir: "results/runs".into(),
        ..TrainConfig::default()
    };
    let params = statquant::experiments::common::warm_params(&rt, &reg, &cfg, warm)?;

    let meta = reg.meta(&model, &variant, StepKind::Probe)?;
    let exec = rt.executor(meta)?;
    let probe = GradVarianceProbe::new(&exec);
    let dataset = statquant::coordinator::make_dataset(
        &cfg,
        &meta.input_shape,
        if model == "transformer" { "markov" } else { "synthimg" },
    );
    let b = dataset.batch(99);
    for bit in bits {
        let rep = probe.quantization_variance(&params, &b.x, &b.y, bit, seeds, 5)?;
        println!(
            "{variant}@{bit}: Var_quant = {} (relative {})",
            fmt_sig(rep.quant_variance, 4),
            fmt_sig(rep.relative(), 4)
        );
    }
    Ok(())
}
