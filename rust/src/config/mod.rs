//! Typed configuration (S13): TOML file -> [`TrainConfig`] with defaults,
//! CLI overrides applied on top (`--set train.lr=0.2`).

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;
use crate::util::toml;

/// Full experiment/run configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct TrainConfig {
    pub model: String,
    pub variant: String,
    pub steps: u64,
    pub lr: f64,
    pub bits: f32,
    /// Linear LR warmup fraction of total steps (paper: 4/90 epochs).
    pub warmup_frac: f64,
    /// Cosine decay to zero after warmup (paper Appendix E).
    pub schedule: String,
    pub eval_every: u64,
    pub eval_batches: u64,
    pub seed: u64,
    pub data: DataConfig,
    pub artifacts_dir: String,
    pub out_dir: String,
    /// Data-parallel simulation workers (1 = single worker).
    pub workers: usize,
    /// Bitwidth for the quantized gradient all-reduce (0 = fp32 reduce).
    pub allreduce_bits: f32,
    /// Quantizer for the all-reduce payloads (ptq|psq|bhq|fp8|bfp).
    pub allreduce_quant: String,
    /// Pool width for the threaded ring engine (1 = serial; results are
    /// bitwise identical for any value, see coordinator/data_parallel).
    pub dp_threads: usize,
    /// How worker gradients are combined: "dense" | "ring".
    pub dp_mode: String,
    /// Backward GEMM arithmetic: "simulate" (f32 quantize–dequantize)
    /// | "int8" (integer-code kernels, i8 x i8 -> i32).
    pub compute: String,
}

#[derive(Clone, Debug, PartialEq)]
pub struct DataConfig {
    pub kind: String,
    pub noise: f32,
    pub hard_frac: f32,
    pub seed: u64,
}

impl Default for DataConfig {
    fn default() -> Self {
        Self {
            kind: "synthimg".into(),
            noise: 0.6,
            hard_frac: 0.08,
            seed: 1234,
        }
    }
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            model: "cnn".into(),
            variant: "bhq".into(),
            steps: 300,
            lr: 0.1,
            bits: 5.0,
            warmup_frac: 0.05,
            schedule: "cosine".into(),
            eval_every: 50,
            eval_batches: 8,
            seed: 0,
            data: DataConfig::default(),
            artifacts_dir: "artifacts".into(),
            out_dir: "runs".into(),
            workers: 1,
            allreduce_bits: 0.0,
            allreduce_quant: "psq".into(),
            dp_threads: 1,
            dp_mode: "dense".into(),
            compute: "simulate".into(),
        }
    }
}

impl TrainConfig {
    pub fn from_toml_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        let j = toml::parse(&text).map_err(|e| anyhow!("{e}"))?;
        Self::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let mut c = Self::default();
        c.apply_json(j)?;
        Ok(c)
    }

    fn apply_json(&mut self, j: &Json) -> Result<()> {
        let get_s = |p: &str| j.path(p).and_then(Json::as_str).map(str::to_string);
        let get_f = |p: &str| j.path(p).and_then(Json::as_f64);
        if let Some(v) = get_s("train.model") {
            self.model = v;
        }
        if let Some(v) = get_s("train.variant") {
            self.variant = v;
        }
        if let Some(v) = get_f("train.steps") {
            self.steps = v as u64;
        }
        if let Some(v) = get_f("train.lr") {
            self.lr = v;
        }
        if let Some(v) = get_f("train.bits") {
            self.bits = v as f32;
        }
        if let Some(v) = get_f("train.warmup_frac") {
            self.warmup_frac = v;
        }
        if let Some(v) = get_s("train.schedule") {
            self.schedule = v;
        }
        if let Some(v) = get_f("train.eval_every") {
            self.eval_every = v as u64;
        }
        if let Some(v) = get_f("train.eval_batches") {
            self.eval_batches = v as u64;
        }
        if let Some(v) = get_f("train.seed") {
            self.seed = v as u64;
        }
        if let Some(v) = get_f("train.workers") {
            self.workers = v as usize;
        }
        if let Some(v) = get_f("train.allreduce_bits") {
            self.allreduce_bits = v as f32;
        }
        if let Some(v) = get_s("train.allreduce_quant") {
            self.allreduce_quant = v;
        }
        if let Some(v) = get_f("train.dp_threads") {
            self.dp_threads = v as usize;
        }
        if let Some(v) = get_s("train.dp_mode") {
            self.dp_mode = v;
        }
        if let Some(v) = get_s("train.compute") {
            self.compute = v;
        }
        if let Some(v) = get_s("data.kind") {
            self.data.kind = v;
        }
        if let Some(v) = get_f("data.noise") {
            self.data.noise = v as f32;
        }
        if let Some(v) = get_f("data.hard_frac") {
            self.data.hard_frac = v as f32;
        }
        if let Some(v) = get_f("data.seed") {
            self.data.seed = v as u64;
        }
        if let Some(v) = get_s("paths.artifacts") {
            self.artifacts_dir = v;
        }
        if let Some(v) = get_s("paths.out") {
            self.out_dir = v;
        }
        Ok(())
    }

    /// Apply a `key=value` override with a dotted key ("train.lr=0.2").
    pub fn set(&mut self, kv: &str) -> Result<()> {
        let (key, val) = kv
            .split_once('=')
            .ok_or_else(|| anyhow!("override must be key=value, got {kv:?}"))?;
        let (key, val) = (key.trim(), val.trim());
        match key {
            "train.model" | "model" => self.model = val.into(),
            "train.variant" | "variant" => self.variant = val.into(),
            "train.steps" | "steps" => self.steps = val.parse()?,
            "train.lr" | "lr" => self.lr = val.parse()?,
            "train.bits" | "bits" => self.bits = val.parse()?,
            "train.warmup_frac" => self.warmup_frac = val.parse()?,
            "train.schedule" => self.schedule = val.into(),
            "train.eval_every" => self.eval_every = val.parse()?,
            "train.eval_batches" => self.eval_batches = val.parse()?,
            "train.seed" | "seed" => self.seed = val.parse()?,
            "train.workers" | "workers" => self.workers = val.parse()?,
            "train.allreduce_bits" => self.allreduce_bits = val.parse()?,
            "train.allreduce_quant" => self.allreduce_quant = val.into(),
            "train.dp_threads" | "dp_threads" => self.dp_threads = val.parse()?,
            "train.dp_mode" | "dp_mode" => self.dp_mode = val.into(),
            "train.compute" | "compute" => self.compute = val.into(),
            "data.kind" => self.data.kind = val.into(),
            "data.noise" => self.data.noise = val.parse()?,
            "data.hard_frac" => self.data.hard_frac = val.parse()?,
            "data.seed" => self.data.seed = val.parse()?,
            "paths.artifacts" => self.artifacts_dir = val.into(),
            "paths.out" => self.out_dir = val.into(),
            _ => bail!("unknown config key {key:?}"),
        }
        Ok(())
    }

    pub fn validate(&self) -> Result<()> {
        if !(1.0..=16.0).contains(&self.bits) {
            bail!("bits must be in [1, 16], got {}", self.bits);
        }
        if self.lr <= 0.0 {
            bail!("lr must be positive");
        }
        if self.workers == 0 {
            bail!("workers must be >= 1");
        }
        if self.dp_threads == 0 {
            bail!("dp_threads must be >= 1");
        }
        if !["dense", "ring"].contains(&self.dp_mode.as_str()) {
            bail!("unknown dp_mode {:?} (expected dense|ring)", self.dp_mode);
        }
        if !["simulate", "int8"].contains(&self.compute.as_str()) {
            bail!("unknown compute {:?} (expected simulate|int8)", self.compute);
        }
        if crate::quant::GradQuantizer::from_name(&self.allreduce_quant).is_none() {
            bail!("unknown allreduce_quant {:?}", self.allreduce_quant);
        }
        if !["cosine", "constant", "step"].contains(&self.schedule.as_str()) {
            bail!("unknown schedule {:?}", self.schedule);
        }
        Ok(())
    }

    pub fn run_name(&self) -> String {
        format!(
            "{}_{}_b{}_s{}",
            self.model, self.variant, self.bits, self.seed
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        TrainConfig::default().validate().unwrap();
    }

    #[test]
    fn toml_roundtrip_fields() {
        let j = toml::parse(
            "[train]\nmodel = \"mlp\"\nlr = 0.05\nbits = 4\nsteps = 10\n\
             [data]\nkind = \"markov\"\nnoise = 0.3\n",
        )
        .unwrap();
        let c = TrainConfig::from_json(&j).unwrap();
        assert_eq!(c.model, "mlp");
        assert_eq!(c.lr, 0.05);
        assert_eq!(c.bits, 4.0);
        assert_eq!(c.steps, 10);
        assert_eq!(c.data.kind, "markov");
        assert_eq!(c.data.noise, 0.3);
        // untouched fields keep defaults
        assert_eq!(c.schedule, "cosine");
    }

    #[test]
    fn cli_overrides() {
        let mut c = TrainConfig::default();
        c.set("lr=0.01").unwrap();
        c.set("train.variant=psq").unwrap();
        c.set("data.noise=0.9").unwrap();
        assert_eq!(c.lr, 0.01);
        assert_eq!(c.variant, "psq");
        assert_eq!(c.data.noise, 0.9);
        assert!(c.set("nope=1").is_err());
        assert!(c.set("malformed").is_err());
    }

    #[test]
    fn validation_rejects_bad_values() {
        let mut c = TrainConfig::default();
        c.bits = 0.5;
        assert!(c.validate().is_err());
        let mut c = TrainConfig::default();
        c.schedule = "exotic".into();
        assert!(c.validate().is_err());
        let mut c = TrainConfig::default();
        c.workers = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn dp_engine_keys_roundtrip_and_validate() {
        let mut c = TrainConfig::default();
        c.set("dp_mode=ring").unwrap();
        c.set("dp_threads=4").unwrap();
        c.set("train.allreduce_quant=bhq").unwrap();
        assert_eq!(c.dp_mode, "ring");
        assert_eq!(c.dp_threads, 4);
        assert_eq!(c.allreduce_quant, "bhq");
        c.validate().unwrap();
        c.dp_mode = "mesh".into();
        assert!(c.validate().is_err());
        c.dp_mode = "ring".into();
        c.allreduce_quant = "int3".into();
        assert!(c.validate().is_err());
        c.allreduce_quant = "psq".into();
        c.dp_threads = 0;
        assert!(c.validate().is_err());

        let j = toml::parse("[train]\ndp_mode = \"ring\"\ndp_threads = 2\n").unwrap();
        let c = TrainConfig::from_json(&j).unwrap();
        assert_eq!((c.dp_mode.as_str(), c.dp_threads), ("ring", 2));
    }

    #[test]
    fn compute_key_roundtrips_and_validates() {
        let mut c = TrainConfig::default();
        assert_eq!(c.compute, "simulate");
        c.set("compute=int8").unwrap();
        assert_eq!(c.compute, "int8");
        c.validate().unwrap();
        c.set("train.compute=simulate").unwrap();
        assert_eq!(c.compute, "simulate");
        c.compute = "fp64".into();
        assert!(c.validate().is_err());

        let j = toml::parse("[train]\ncompute = \"int8\"\n").unwrap();
        let c = TrainConfig::from_json(&j).unwrap();
        assert_eq!(c.compute, "int8");
    }

    #[test]
    fn run_name_is_stable() {
        let c = TrainConfig::default();
        assert_eq!(c.run_name(), "cnn_bhq_b5_s0");
    }
}
