//! # StatQuant — a statistical framework for low-bitwidth training
//!
//! Reproduction of Chen, Gai, Yao, Mahoney & Gonzalez, *"A Statistical
//! Framework for Low-bitwidth Training of Deep Neural Networks"*
//! (NeurIPS 2020), as a three-layer Rust + JAX + Pallas system:
//!
//! - **L1** (`python/compile/kernels/`): Pallas kernels for the fused
//!   stochastic-rounding quantizer and the blocked quantized GEMM.
//! - **L2** (`python/compile/`): the paper's gradient quantizers
//!   (PTQ/PSQ/BHQ + FP8/BFP extension formats) and the FQT backward pass
//!   (Eq. 6) inside JAX models, AOT-lowered to HLO text.
//! - **L3** (this crate): the training framework — a pluggable executor
//!   runtime (pure-Rust native backend by default, PJRT behind the
//!   `pjrt` cargo feature), coordinator (train loop, LR schedules,
//!   checkpointing, data-parallel simulation with quantized all-reduce),
//!   synthetic data substrates, native quantizers, statistics engine,
//!   and the experiment harness that regenerates every table and figure
//!   in the paper's evaluation.
//!
//! Python never runs on the training path: `make artifacts` lowers the
//! models once (or `statquant gen-artifacts` writes the native-backend
//! set); the `statquant` binary is self-contained afterwards.
//!
//! See DESIGN.md for the system inventory and experiment index, and
//! EXPERIMENTS.md for paper-vs-measured results.

pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod metrics;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod stats;
pub mod util;
