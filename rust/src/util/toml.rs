//! Minimal TOML-subset parser for the config system (S13).
//!
//! Supports the subset our configs use: `[section]` / `[a.b]` tables,
//! `key = value` with strings, integers, floats, booleans, and flat
//! arrays. Values land in the same [`Json`] tree the rest of the stack
//! uses, keyed by dotted path — `config::TrainConfig` pulls typed fields
//! out of it with defaults.

use super::json::Json;
use std::collections::BTreeMap;

#[derive(Debug, thiserror::Error)]
#[error("toml parse error on line {line}: {msg}")]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

/// Parse TOML text into a nested Json object.
pub fn parse(text: &str) -> Result<Json, TomlError> {
    let mut root: BTreeMap<String, Json> = BTreeMap::new();
    let mut section: Vec<String> = Vec::new();

    for (ln, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| err(ln, "unterminated table header"))?
                .trim();
            if name.is_empty() {
                return Err(err(ln, "empty table name"));
            }
            section = name.split('.').map(|s| s.trim().to_string()).collect();
            // materialize the table
            ensure_table(&mut root, &section, ln)?;
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| err(ln, "expected key = value"))?;
        let key = line[..eq].trim();
        let val = parse_value(line[eq + 1..].trim(), ln)?;
        if key.is_empty() {
            return Err(err(ln, "empty key"));
        }
        let tbl = ensure_table(&mut root, &section, ln)?;
        tbl.insert(key.to_string(), val);
    }
    Ok(Json::Obj(root))
}

fn err(line: usize, msg: &str) -> TomlError {
    TomlError {
        line: line + 1,
        msg: msg.to_string(),
    }
}

fn strip_comment(line: &str) -> &str {
    // naive but sufficient: '#' outside quotes starts a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn ensure_table<'a>(
    root: &'a mut BTreeMap<String, Json>,
    path: &[String],
    ln: usize,
) -> Result<&'a mut BTreeMap<String, Json>, TomlError> {
    let mut cur = root;
    for part in path {
        let entry = cur
            .entry(part.clone())
            .or_insert_with(|| Json::Obj(BTreeMap::new()));
        cur = match entry {
            Json::Obj(m) => m,
            _ => return Err(err(ln, "key redefined as table")),
        };
    }
    Ok(cur)
}

fn parse_value(s: &str, ln: usize) -> Result<Json, TomlError> {
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| err(ln, "unterminated string"))?;
        let mut out = String::new();
        let mut chars = inner.chars();
        while let Some(c) = chars.next() {
            if c == '\\' {
                match chars.next() {
                    Some('n') => out.push('\n'),
                    Some('t') => out.push('\t'),
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    _ => return Err(err(ln, "bad escape")),
                }
            } else {
                out.push(c);
            }
        }
        return Ok(Json::Str(out));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| err(ln, "unterminated array"))?;
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            let p = part.trim();
            if !p.is_empty() {
                items.push(parse_value(p, ln)?);
            }
        }
        return Ok(Json::Arr(items));
    }
    match s {
        "true" => return Ok(Json::Bool(true)),
        "false" => return Ok(Json::Bool(false)),
        _ => {}
    }
    s.parse::<f64>()
        .map(Json::Num)
        .map_err(|_| err(ln, &format!("bad value {s:?}")))
}

fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_config_example() {
        let text = r#"
# experiment config
[train]
model = "cnn"          # which artifact family
variant = "bhq"
steps = 400
lr = 0.1
bits = 5.0
warmup_frac = 0.05

[data]
kind = "synthimg"
classes = 10
noise = 0.25

[probe]
bits = [4, 5, 6, 7, 8]
seeds = 16
"#;
        let j = parse(text).unwrap();
        assert_eq!(j.path("train.model").unwrap().as_str(), Some("cnn"));
        assert_eq!(j.path("train.steps").unwrap().as_usize(), Some(400));
        assert_eq!(j.path("data.noise").unwrap().as_f64(), Some(0.25));
        assert_eq!(j.path("probe.bits").unwrap().as_arr().unwrap().len(), 5);
    }

    #[test]
    fn nested_tables_and_strings_with_escapes() {
        let j = parse("[a.b]\nk = \"x\\ny\"\n").unwrap();
        assert_eq!(j.path("a.b.k").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn comments_and_blank_lines_ignored() {
        let j = parse("\n# hi\nk = 1 # trailing\n").unwrap();
        assert_eq!(j.get("k").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = parse("k =").unwrap_err();
        assert_eq!(e.line, 1);
        let e = parse("ok = 1\n[bad\n").unwrap_err();
        assert_eq!(e.line, 2);
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let j = parse("k = \"a#b\"\n").unwrap();
        assert_eq!(j.get("k").unwrap().as_str(), Some("a#b"));
    }
}
