//! Minimal JSON codec (serde is not available offline; DESIGN.md §6 S13).
//!
//! Parses the artifact metadata sidecars written by `python/compile/aot.py`
//! and serializes experiment results / metrics. Supports the full JSON
//! grammar except exotic number forms; numbers are f64 (ints round-trip
//! exactly up to 2^53, far beyond anything in our metadata).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// `obj.get(key)` chain over a dotted path ("a.b.c").
    pub fn path(&self, dotted: &str) -> Option<&Json> {
        let mut cur = self;
        for part in dotted.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    // -- writer ---------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    /// Single-line form for JSONL sinks. Same escaping as the pretty
    /// writer ([`write_escaped`]), so non-ASCII and control characters
    /// stay valid JSON.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    /// Compact-serialize into an existing buffer.
    pub fn write_compact(&self, out: &mut String) {
        self.write(out, 0, false);
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    x.write(out, indent + 1, pretty);
                }
                if pretty && !v.is_empty() {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    write_escaped(out, k);
                    out.push_str(if pretty { ": " } else { ":" });
                    x.write(out, indent + 1, pretty);
                }
                if pretty && !m.is_empty() {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl<T: Into<Json>> FromIterator<T> for Json {
    fn from_iter<I: IntoIterator<Item = T>>(iter: I) -> Self {
        Json::Arr(iter.into_iter().map(Into::into).collect())
    }
}

/// Convenience builder: `obj([("k", Json::from(1.0)), ...])`.
pub fn obj<I: IntoIterator<Item = (&'static str, Json)>>(items: I) -> Json {
    Json::Obj(
        items
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect(),
    )
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && self.b[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.pos += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 scalar
                    let s = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("bad utf8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_artifact_metadata_shape() {
        let s = r#"{"model":"mlp","n_params":26122,
            "inputs":[{"shape":[26122],"dtype":"float32"}],
            "lower_seconds":1.5,"nested":{"a":[1,2,3]}}"#;
        let j = Json::parse(s).unwrap();
        assert_eq!(j.get("model").unwrap().as_str(), Some("mlp"));
        assert_eq!(j.get("n_params").unwrap().as_usize(), Some(26122));
        assert_eq!(
            j.path("inputs").unwrap().as_arr().unwrap()[0]
                .get("dtype")
                .unwrap()
                .as_str(),
            Some("float32")
        );
        assert_eq!(j.path("nested.a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn roundtrip() {
        let j = obj([
            ("name", Json::from("fig3a")),
            ("bits", (2..=8).map(|b| b as f64).collect()),
            ("ok", Json::from(true)),
            ("none", Json::Null),
            ("v", Json::from(1.25)),
        ]);
        let s = j.to_string_pretty();
        let j2 = Json::parse(&s).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn escapes_roundtrip() {
        let j = Json::Str("a\"b\\c\nd\te\u{1}".to_string());
        let s = j.to_string_pretty();
        assert_eq!(Json::parse(&s).unwrap(), j);
    }

    #[test]
    fn compact_handles_non_ascii_and_controls() {
        // The old metrics write_compact used Rust's {:?} debug escaping,
        // which emits \u{1f600}-style escapes — invalid JSON. The shared
        // writer must keep raw UTF-8 and only \uXXXX-escape controls.
        let j = obj([
            ("s", Json::from("é😀\u{1}\"\\")),
            ("n", Json::from(1.5)),
            ("a", (0..2).map(|b| b as f64).collect()),
        ]);
        let s = j.to_string_compact();
        assert!(!s.contains('\n'));
        assert!(!s.contains("\\u{"), "rust debug escape leaked: {s}");
        assert!(s.contains("😀"), "emoji must stay raw utf-8: {s}");
        assert_eq!(Json::parse(&s).unwrap(), j);
        let mut buf = String::from("x");
        j.write_compact(&mut buf);
        assert_eq!(&buf[1..], s);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{} trailing").is_err());
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(Json::parse("0").unwrap().as_usize(), Some(0));
    }
}
