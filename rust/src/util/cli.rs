//! Tiny subcommand + flag parser (clap is unavailable offline).
//!
//! Grammar: `statquant <command> [positional...] [--flag value] [--switch]`.
//! Flags may repeat (`--set a=1 --set b=2`).

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    flags: BTreeMap<String, Vec<String>>,
    switches: Vec<String>,
    /// Which flags/switches were consumed via accessors (unknown-flag check).
    known: std::cell::RefCell<Vec<String>>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Self> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare -- not supported");
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.entry(k.to_string()).or_default().push(v.to_string());
                } else if it.peek().is_some_and(|n| !n.starts_with("--")) {
                    let v = it.next().unwrap().clone();
                    out.flags.entry(name.to_string()).or_default().push(v);
                } else {
                    out.switches.push(name.to_string());
                }
            } else if out.command.is_empty() {
                out.command = a.clone();
            } else {
                out.positional.push(a.clone());
            }
        }
        Ok(out)
    }

    fn mark(&self, name: &str) {
        self.known.borrow_mut().push(name.to_string());
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.mark(name);
        self.flags.get(name).and_then(|v| v.last()).map(String::as_str)
    }

    pub fn flag_all(&self, name: &str) -> Vec<&str> {
        self.mark(name);
        self.flags
            .get(name)
            .map(|v| v.iter().map(String::as_str).collect())
            .unwrap_or_default()
    }

    pub fn flag_parse<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.flag(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow!("--{name} {s:?}: {e}")),
        }
    }

    pub fn switch(&self, name: &str) -> bool {
        self.mark(name);
        self.switches.iter().any(|s| s == name)
    }

    /// Error on flags that no accessor ever looked at (typo guard).
    pub fn check_unknown(&self) -> Result<()> {
        let known = self.known.borrow();
        for k in self.flags.keys() {
            if !known.iter().any(|n| n == k) {
                bail!("unknown flag --{k}");
            }
        }
        for s in &self.switches {
            if !known.iter().any(|n| n == s) {
                bail!("unknown switch --{s}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        let argv: Vec<String> = s.split_whitespace().map(String::from).collect();
        Args::parse(&argv).unwrap()
    }

    #[test]
    fn command_positionals_flags_switches() {
        let a = parse("train config.toml --set lr=0.1 --set bits=4 --verbose --out dir");
        assert_eq!(a.command, "train");
        assert_eq!(a.positional, vec!["config.toml"]);
        assert_eq!(a.flag_all("set"), vec!["lr=0.1", "bits=4"]);
        assert_eq!(a.flag("out"), Some("dir"));
        assert!(a.switch("verbose"));
        assert!(!a.switch("quiet"));
        a.check_unknown().unwrap();
    }

    #[test]
    fn equals_form() {
        let a = parse("exp fig3a --bits=4,5,6");
        assert_eq!(a.flag("bits"), Some("4,5,6"));
    }

    #[test]
    fn typed_parse() {
        let a = parse("x --n 12 --f 0.5");
        assert_eq!(a.flag_parse::<u64>("n").unwrap(), Some(12));
        assert_eq!(a.flag_parse::<f64>("f").unwrap(), Some(0.5));
        let b = parse("x --n twelve");
        assert!(b.flag_parse::<u64>("n").is_err());
    }

    #[test]
    fn unknown_flag_detected() {
        let a = parse("train --unknown 3");
        assert!(a.check_unknown().is_err());
        let b = parse("train --known 3");
        b.flag("known");
        b.check_unknown().unwrap();
    }

    #[test]
    fn last_flag_wins() {
        let a = parse("x --lr 0.1 --lr 0.2");
        assert_eq!(a.flag("lr"), Some("0.2"));
    }
}
