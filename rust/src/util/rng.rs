//! Deterministic PRNG substrate.
//!
//! The offline image ships no `rand` crate, so the coordinator carries its
//! own generators: [`SplitMix64`] for seeding / stream-splitting and
//! [`Pcg32`] (PCG-XSH-RR 64/32, O'Neill 2014) as the workhorse stream.
//! Everything downstream (data synthesis, native quantizers, property
//! tests, data-parallel workers) takes an explicit `&mut Pcg32`, so every
//! run is reproducible from a single u64 seed.

/// SplitMix64 — tiny, full-period seeder (Steele et al., 2014).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSH-RR 64/32: 64-bit state, 32-bit output, excellent statistical
/// quality for its size and trivially seekable into independent streams.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6_364_136_223_846_793_005;

impl Pcg32 {
    /// Seed a generator; `stream` selects one of 2^63 independent
    /// sequences (used to give each worker/layer its own stream).
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut rng = Self {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.state = sm.next_u64();
        rng.step();
        rng
    }

    /// Derive an independent child stream (hash-fold the tag).
    pub fn fork(&mut self, tag: u64) -> Pcg32 {
        let s = (self.next_u64() ^ tag).wrapping_mul(PCG_MULT);
        Pcg32::new(s, tag.wrapping_add(0x632B_E5AB))
    }

    #[inline]
    fn step(&mut self) {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.step();
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        (u64::from(self.next_u32()) << 32) | u64::from(self.next_u32())
    }

    /// Uniform f32 in [0, 1) with 24 bits of mantissa entropy.
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f64 in [0, 1) with 53 bits.
    #[inline]
    pub fn uniform_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) (Lemire's multiply-shift with rejection).
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u32();
            let m = u64::from(x) * u64::from(n);
            let lo = m as u32;
            if lo >= n || lo >= (n.wrapping_neg() % n) {
                return (m >> 32) as u32;
            }
        }
    }

    /// Standard normal via Box–Muller (caches the second variate).
    pub fn normal(&mut self) -> f32 {
        // no cached pair: keep the generator Clone-simple; two uniforms
        // per call is fine off the hot path.
        let u1 = self.uniform_f64().max(1e-300);
        let u2 = self.uniform_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Fill a slice with uniform [0,1) noise (the SR noise path).
    pub fn fill_uniform(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.uniform();
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u32) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference values for seed 1234567 (computed from the canonical
        // Java/C reference implementation).
        let mut sm = SplitMix64::new(0);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // determinism
        let mut sm2 = SplitMix64::new(0);
        assert_eq!(a, sm2.next_u64());
    }

    #[test]
    fn pcg_deterministic_and_stream_independent() {
        let mut a = Pcg32::new(42, 0);
        let mut b = Pcg32::new(42, 0);
        let mut c = Pcg32::new(42, 1);
        let xs: Vec<u32> = (0..8).map(|_| a.next_u32()).collect();
        let ys: Vec<u32> = (0..8).map(|_| b.next_u32()).collect();
        let zs: Vec<u32> = (0..8).map(|_| c.next_u32()).collect();
        assert_eq!(xs, ys);
        assert_ne!(xs, zs);
    }

    #[test]
    fn uniform_in_range_and_mean_ok() {
        let mut r = Pcg32::new(7, 3);
        let n = 100_000;
        let mut sum = 0.0f64;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += f64::from(u);
        }
        let mean = sum / f64::from(n);
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_unbiased_small_range() {
        let mut r = Pcg32::new(11, 0);
        let mut counts = [0u32; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::new(5, 9);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = f64::from(r.normal());
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / f64::from(n);
        let var = s2 / f64::from(n) - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::new(3, 3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_diverge() {
        let mut root = Pcg32::new(1, 0);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let xs: Vec<u32> = (0..4).map(|_| a.next_u32()).collect();
        let ys: Vec<u32> = (0..4).map(|_| b.next_u32()).collect();
        assert_ne!(xs, ys);
    }
}
