//! proptest-lite: seeded randomized property testing with shrinking on
//! the case *index* (re-runnable by seed), since the real proptest crate
//! is unavailable offline.
//!
//! Usage:
//! ```ignore
//! check(200, |g| {
//!     let n = g.usize(1..=64);
//!     let xs = g.vec_f32(n, -10.0..10.0);
//!     prop_assert(invariant(&xs), format!("failed for {xs:?}"))
//! });
//! ```

use crate::util::rng::Pcg32;

/// Per-case random value source.
pub struct Gen {
    pub rng: Pcg32,
    pub case: u64,
}

impl Gen {
    pub fn usize(&mut self, range: std::ops::RangeInclusive<usize>) -> usize {
        let (lo, hi) = (*range.start(), *range.end());
        lo + self.rng.below((hi - lo + 1) as u32) as usize
    }

    pub fn f32(&mut self, range: std::ops::Range<f32>) -> f32 {
        range.start + self.rng.uniform() * (range.end - range.start)
    }

    pub fn normal(&mut self) -> f32 {
        self.rng.normal()
    }

    pub fn bool(&mut self, p: f32) -> bool {
        self.rng.uniform() < p
    }

    pub fn vec_f32(&mut self, n: usize, range: std::ops::Range<f32>) -> Vec<f32> {
        (0..n).map(|_| self.f32(range.clone())).collect()
    }

    pub fn vec_normal(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * scale).collect()
    }

    pub fn rng(&mut self) -> &mut Pcg32 {
        &mut self.rng
    }
}

/// Outcome of one property case.
pub type CaseResult = Result<(), String>;

pub fn prop_assert(cond: bool, msg: impl Into<String>) -> CaseResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Run `cases` random cases of `prop`. Panics with the failing case index
/// and seed so the exact case can be replayed (`PROPTEST_SEED` env var).
pub fn check<F: FnMut(&mut Gen) -> CaseResult>(cases: u64, mut prop: F) {
    let seed: u64 = std::env::var("PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE);
    for case in 0..cases {
        let mut g = Gen {
            rng: Pcg32::new(seed, case),
            case,
        };
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property failed at case {case} (seed {seed}, rerun with \
                 PROPTEST_SEED={seed}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true() {
        check(50, |g| {
            let n = g.usize(1..=10);
            prop_assert(n >= 1 && n <= 10, "range")
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn fails_loudly() {
        check(50, |g| {
            let x = g.f32(0.0..1.0);
            prop_assert(x < 0.5, format!("x = {x}"))
        });
    }

    #[test]
    fn deterministic_across_runs() {
        let mut seen = Vec::new();
        check(5, |g| {
            seen.push(g.usize(0..=1000));
            Ok(())
        });
        let mut seen2 = Vec::new();
        check(5, |g| {
            seen2.push(g.usize(0..=1000));
            Ok(())
        });
        assert_eq!(seen, seen2);
    }
}
