//! In-repo substrates: PRNG, JSON/TOML codecs, CLI parsing, proptest-lite.
//!
//! The offline build environment ships only the `xla` crate's dependency
//! tree, so these standard-ecosystem pieces are implemented here as
//! first-class, fully-tested modules (DESIGN.md §6, S13).

pub mod cli;
pub mod json;
pub mod bench;
pub mod proptest;
pub mod rng;
pub mod toml;
