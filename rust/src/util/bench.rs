//! Benchmark harness substrate (criterion is unavailable offline).
//!
//! Measures wall time with warmup, reports median / mean / p10 / p50 /
//! p90 / p99 and derived throughput, and emits human-readable lines, a
//! CSV under `results/bench/`, and — via [`Bench::finish`] — a
//! `BENCH_<name>.json` metrics-registry snapshot so bench trajectories
//! ride the same exporter as run metrics. Used by `cargo bench` targets
//! (harness=false).

use std::time::Instant;

use anyhow::Result;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub p10_ns: f64,
    pub p50_ns: f64,
    pub p90_ns: f64,
    pub p99_ns: f64,
    /// Optional work units per iteration (elements, FLOPs) for throughput.
    pub units_per_iter: f64,
}

impl BenchResult {
    pub fn units_per_sec(&self) -> f64 {
        self.units_per_iter / (self.median_ns * 1e-9)
    }

    pub fn human(&self) -> String {
        let t = fmt_ns(self.median_ns);
        if self.units_per_iter > 0.0 {
            format!(
                "{:<44} {:>12}/iter  [{} .. {}]  {:>12.3e} units/s",
                self.name,
                t,
                fmt_ns(self.p10_ns),
                fmt_ns(self.p90_ns),
                self.units_per_sec()
            )
        } else {
            format!(
                "{:<44} {:>12}/iter  [{} .. {}]",
                self.name,
                t,
                fmt_ns(self.p10_ns),
                fmt_ns(self.p90_ns)
            )
        }
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Collected results + CSV / metrics-registry emission.
#[derive(Default)]
pub struct Bench {
    pub results: Vec<BenchResult>,
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time `f` adaptively: warm up, then run until ~`budget_ms` or 256
    /// samples. `units` is per-iteration work for throughput reporting.
    pub fn run<F: FnMut()>(&mut self, name: &str, units: f64, mut f: F) -> &BenchResult {
        // warmup
        let t0 = Instant::now();
        f();
        let first = t0.elapsed().as_nanos() as f64;
        let target_ms = std::env::var("BENCH_BUDGET_MS")
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .unwrap_or(800.0);
        let iters = ((target_ms * 1e6 / first.max(1.0)) as usize).clamp(5, 256);
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_nanos() as f64);
        }
        samples.sort_by(f64::total_cmp);
        let pct = |q: usize| samples[(samples.len() * q / 100).min(samples.len() - 1)];
        let median = samples[samples.len() / 2];
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let r = BenchResult {
            name: name.to_string(),
            iters,
            median_ns: median,
            mean_ns: mean,
            p10_ns: pct(10),
            p50_ns: median,
            p90_ns: pct(90),
            p99_ns: pct(99),
            units_per_iter: units,
        };
        println!("{}", r.human());
        self.results.push(r);
        self.results.last().unwrap()
    }

    /// Write all results to `results/bench/<file>.csv`.
    pub fn write_csv(&self, file: &str) -> Result<()> {
        let mut w = crate::metrics::CsvWriter::create(
            format!("results/bench/{file}.csv"),
            &[
                "name",
                "iters",
                "median_ns",
                "mean_ns",
                "p10_ns",
                "p50_ns",
                "p90_ns",
                "p99_ns",
                "units_per_iter",
            ],
        )?;
        for r in &self.results {
            w.row(&[
                r.name.clone(),
                r.iters.to_string(),
                r.median_ns.to_string(),
                r.mean_ns.to_string(),
                r.p10_ns.to_string(),
                r.p50_ns.to_string(),
                r.p90_ns.to_string(),
                r.p99_ns.to_string(),
                r.units_per_iter.to_string(),
            ])?;
        }
        Ok(())
    }

    /// Publish every result as labeled gauges in the global metrics
    /// registry (`bench_median_ns{bench="..."}` etc.). Requires obs to
    /// be enabled ([`crate::obs::set_enabled`]) — gauge sets are gated.
    pub fn export_metrics(&self) {
        let m = crate::obs::metrics();
        for r in &self.results {
            let labels = [("bench", r.name.as_str())];
            let l = |base: &str| crate::obs::registry::labeled(base, &labels);
            m.gauge(&l("bench_median_ns"), "bench median ns/iter").set(r.median_ns);
            m.gauge(&l("bench_mean_ns"), "bench mean ns/iter").set(r.mean_ns);
            m.gauge(&l("bench_p50_ns"), "bench p50 ns/iter").set(r.p50_ns);
            m.gauge(&l("bench_p99_ns"), "bench p99 ns/iter").set(r.p99_ns);
            if r.units_per_iter > 0.0 {
                m.gauge(&l("bench_units_per_sec"), "bench throughput")
                    .set(r.units_per_sec());
            }
        }
    }

    /// CSV + registry export + `results/bench/BENCH_<file>.json` snapshot
    /// — the uniform trajectory artifact every bench target emits.
    pub fn finish(&self, file: &str) -> Result<()> {
        self.export_metrics();
        self.write_csv(file)?;
        let snap = crate::obs::metrics().snapshot_json().to_string_pretty();
        std::fs::write(format!("results/bench/BENCH_{file}.json"), snap)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        std::env::set_var("BENCH_BUDGET_MS", "20");
        let mut b = Bench::new();
        let mut acc = 0u64;
        let r = b.run("spin", 1000.0, || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
        });
        assert!(r.median_ns > 0.0);
        assert!(r.units_per_sec() > 0.0);
        assert_eq!(r.p50_ns, r.median_ns);
        assert!(r.p10_ns <= r.p50_ns);
        assert!(r.p50_ns <= r.p90_ns);
        assert!(r.p90_ns <= r.p99_ns);
        std::hint::black_box(acc);
    }

    #[test]
    fn export_registers_labeled_gauges() {
        let _g = crate::obs::testutil::serial();
        crate::obs::set_enabled(true);
        let b = Bench {
            results: vec![BenchResult {
                name: "bench_test_export".into(),
                iters: 5,
                median_ns: 100.0,
                mean_ns: 110.0,
                p10_ns: 90.0,
                p50_ns: 100.0,
                p90_ns: 130.0,
                p99_ns: 150.0,
                units_per_iter: 10.0,
            }],
        };
        b.export_metrics();
        let text = crate::obs::metrics().render_prometheus();
        assert!(
            text.contains("bench_p99_ns{bench=\"bench_test_export\"} 150"),
            "{text}"
        );
    }

    #[test]
    fn fmt_ns_scales() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }
}
