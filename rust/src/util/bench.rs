//! Benchmark harness substrate (criterion is unavailable offline).
//!
//! Measures wall time with warmup, reports median / mean / p10 / p90 and
//! derived throughput, and emits both human-readable lines and a CSV
//! under `results/bench/`. Used by `cargo bench` targets (harness=false).

use std::time::Instant;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    /// Optional work units per iteration (elements, FLOPs) for throughput.
    pub units_per_iter: f64,
}

impl BenchResult {
    pub fn units_per_sec(&self) -> f64 {
        self.units_per_iter / (self.median_ns * 1e-9)
    }

    pub fn human(&self) -> String {
        let t = fmt_ns(self.median_ns);
        if self.units_per_iter > 0.0 {
            format!(
                "{:<44} {:>12}/iter  [{} .. {}]  {:>12.3e} units/s",
                self.name,
                t,
                fmt_ns(self.p10_ns),
                fmt_ns(self.p90_ns),
                self.units_per_sec()
            )
        } else {
            format!(
                "{:<44} {:>12}/iter  [{} .. {}]",
                self.name,
                t,
                fmt_ns(self.p10_ns),
                fmt_ns(self.p90_ns)
            )
        }
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Collected results + CSV emission.
#[derive(Default)]
pub struct Bench {
    pub results: Vec<BenchResult>,
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time `f` adaptively: warm up, then run until ~`budget_ms` or 256
    /// samples. `units` is per-iteration work for throughput reporting.
    pub fn run<F: FnMut()>(&mut self, name: &str, units: f64, mut f: F) -> &BenchResult {
        // warmup
        let t0 = Instant::now();
        f();
        let first = t0.elapsed().as_nanos() as f64;
        let target_ms = std::env::var("BENCH_BUDGET_MS")
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .unwrap_or(800.0);
        let iters = ((target_ms * 1e6 / first.max(1.0)) as usize).clamp(5, 256);
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_nanos() as f64);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = samples[samples.len() / 2];
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let p10 = samples[samples.len() / 10];
        let p90 = samples[samples.len() * 9 / 10];
        let r = BenchResult {
            name: name.to_string(),
            iters,
            median_ns: median,
            mean_ns: mean,
            p10_ns: p10,
            p90_ns: p90,
            units_per_iter: units,
        };
        println!("{}", r.human());
        self.results.push(r);
        self.results.last().unwrap()
    }

    /// Write all results to `results/bench/<file>.csv`.
    pub fn write_csv(&self, file: &str) -> std::io::Result<()> {
        std::fs::create_dir_all("results/bench")?;
        let mut out = String::from("name,iters,median_ns,mean_ns,p10_ns,p90_ns,units_per_iter\n");
        for r in &self.results {
            out.push_str(&format!(
                "{},{},{},{},{},{},{}\n",
                r.name, r.iters, r.median_ns, r.mean_ns, r.p10_ns, r.p90_ns, r.units_per_iter
            ));
        }
        std::fs::write(format!("results/bench/{file}.csv"), out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        std::env::set_var("BENCH_BUDGET_MS", "20");
        let mut b = Bench::new();
        let mut acc = 0u64;
        let r = b.run("spin", 1000.0, || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
        });
        assert!(r.median_ns > 0.0);
        assert!(r.units_per_sec() > 0.0);
        std::hint::black_box(acc);
    }

    #[test]
    fn fmt_ns_scales() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }
}
