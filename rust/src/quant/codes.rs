//! Typed integer-code storage for the low-bitwidth GEMM path.
//!
//! [`CodeMat`] replaces the old convention of parking integral codes in
//! an f32 [`super::Mat`]: codes are stored as *centered* `i8`
//! (`stored = raw - center`), so an out-of-range code is a type error
//! (or a counted saturation), not a silent convention violation. The
//! affine reconstruction is carried separately in [`CodeScales`]:
//!
//! ```text
//! x  ≈  raw / scale + lo
//!    =  (stored + center) / scale + lo
//!    =  stored * inv + zero,      inv = 1/scale,
//!                                 zero = lo + center/scale.
//! ```
//!
//! Centering matters for the integer kernels: zero-padded panel tails
//! contribute exactly `0 * b = 0` to the i32 dot products, and the
//! worst-case product magnitude `128 * 128 = 16384` leaves i32
//! accumulation exact for any K < 2^17.
//!
//! Integer storage cannot carry NaN, so poisoning (the NaN-input
//! contract of `quant/mod.rs::poisoned`) is tracked as a per-row mask
//! plus NaN `inv`/`zero` scales — any arithmetic consumer of a poisoned
//! row still propagates NaN through the epilogue.

/// Center offset for raw codes in `[0, nbins]`: roughly `nbins/2`,
/// capped so that `raw - center` always fits the i8 low end
/// (`255 -> 128`, `15 -> 8`, `1 -> 1`).
pub fn center_for(nbins: f32) -> i32 {
    ((nbins.ceil() as i32 + 1) / 2).min(128)
}

/// Center and saturate one raw code. Returns the stored i8 plus whether
/// saturation moved the value (only possible for one-sided quantizers
/// like BHQ whose raw codes may exceed `nbins`).
#[inline]
pub fn center_code(raw: f32, center: i32) -> (i8, bool) {
    let c = raw - center as f32;
    let s = c.clamp(-128.0, 127.0);
    (s as i8, s != c)
}

/// Dense row-major matrix of centered `i8` codes.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CodeMat {
    pub rows: usize,
    pub cols: usize,
    /// Centered codes, row-major: `data[i*cols + j] = raw - center`.
    pub data: Vec<i8>,
    /// The centering offset shared by every code in the matrix.
    pub center: i32,
    /// Per-row poison mask (NaN input rows; see module docs).
    pub poisoned: Vec<bool>,
    /// Codes moved by the saturating store (see [`center_code`]).
    pub saturated: u64,
}

impl CodeMat {
    pub fn zeros(rows: usize, cols: usize, center: i32) -> Self {
        CodeMat {
            rows,
            cols,
            data: vec![0; rows * cols],
            center,
            poisoned: vec![false; rows],
            saturated: 0,
        }
    }

    /// Reshape in place, never shrinking capacity (arena-friendly).
    pub fn resize(&mut self, rows: usize, cols: usize, center: i32) {
        self.rows = rows;
        self.cols = cols;
        self.center = center;
        self.data.clear();
        self.data.resize(rows * cols, 0);
        self.poisoned.clear();
        self.poisoned.resize(rows, false);
        self.saturated = 0;
    }

    pub fn len(&self) -> usize {
        self.rows * self.cols
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[i8] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [i8] {
        let c = self.cols;
        &mut self.data[i * c..(i + 1) * c]
    }

    /// Store a raw (uncentered) code, saturating and counting moves.
    #[inline]
    pub fn store_raw(&mut self, i: usize, j: usize, raw: f32) {
        let (s, moved) = center_code(raw, self.center);
        self.data[i * self.cols + j] = s;
        self.saturated += u64::from(moved);
    }

    /// Raw (uncentered) code at `(i, j)`; NaN semantics are *not*
    /// represented here — check [`Self::is_poisoned_row`] first.
    #[inline]
    pub fn raw_at(&self, i: usize, j: usize) -> i32 {
        i32::from(self.data[i * self.cols + j]) + self.center
    }

    #[inline]
    pub fn is_poisoned_row(&self, i: usize) -> bool {
        self.poisoned[i]
    }

    pub fn poison_row(&mut self, i: usize) {
        self.poisoned[i] = true;
        self.row_mut(i).fill(0);
    }

    pub fn poison_all(&mut self) {
        self.poisoned.iter_mut().for_each(|p| *p = true);
        self.data.fill(0);
    }

    pub fn any_poisoned(&self) -> bool {
        self.poisoned.iter().any(|&p| p)
    }

    /// Raw codes as f32 for the analysis paths (Fig-4 histograms), with
    /// poisoned rows rendered as NaN — the exact values the old
    /// codes-as-f32 `Mat` carried.
    pub fn raw_f32(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.len());
        for i in 0..self.rows {
            if self.poisoned[i] {
                out.extend(std::iter::repeat_n(f32::NAN, self.cols));
            } else {
                out.extend(
                    self.row(i)
                        .iter()
                        .map(|&c| (i32::from(c) + self.center) as f32),
                );
            }
        }
        out
    }
}

/// Affine reconstruction factors for a [`CodeMat`]: either one
/// (`per_row == false`, PTQ) or one per row (PSQ). Poisoned scopes carry
/// NaN so reconstruction propagates the poison arithmetically.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CodeScales {
    pub per_row: bool,
    /// Bin size 1/scale (len 1 per-tensor, `rows` per-row).
    pub inv: Vec<f32>,
    /// `lo + center/scale` (same length as `inv`).
    pub zero: Vec<f32>,
}

impl CodeScales {
    pub fn resize_tensor(&mut self) {
        self.per_row = false;
        self.inv.clear();
        self.inv.resize(1, 0.0);
        self.zero.clear();
        self.zero.resize(1, 0.0);
    }

    pub fn resize_rows(&mut self, rows: usize) {
        self.per_row = true;
        self.inv.clear();
        self.inv.resize(rows, 0.0);
        self.zero.clear();
        self.zero.resize(rows, 0.0);
    }

    #[inline]
    pub fn inv_at(&self, i: usize) -> f32 {
        if self.per_row {
            self.inv[i]
        } else {
            self.inv[0]
        }
    }

    #[inline]
    pub fn zero_at(&self, i: usize) -> f32 {
        if self.per_row {
            self.zero[i]
        } else {
            self.zero[0]
        }
    }

    /// Dequantize one centered code from row `i`.
    #[inline]
    pub fn deq(&self, i: usize, code: i8) -> f32 {
        f32::from(code) * self.inv_at(i) + self.zero_at(i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn center_values_match_bit_widths() {
        assert_eq!(center_for(255.0), 128); // 8-bit
        assert_eq!(center_for(15.0), 8); // 4-bit
        assert_eq!(center_for(1.0), 1); // 1-bit
        assert_eq!(center_for(3.0), 2); // 2-bit
    }

    #[test]
    fn centered_codes_cover_full_raw_range_without_saturation() {
        for nbins in [1.0f32, 3.0, 15.0, 255.0] {
            let center = center_for(nbins);
            for raw in 0..=(nbins as i32) {
                let (s, moved) = center_code(raw as f32, center);
                assert!(!moved, "nbins {nbins} raw {raw} saturated");
                assert_eq!(i32::from(s) + center, raw);
            }
        }
    }

    #[test]
    fn store_saturates_and_counts_one_sided_overflow() {
        let mut m = CodeMat::zeros(1, 2, center_for(15.0));
        m.store_raw(0, 0, 15.0);
        m.store_raw(0, 1, 300.0); // BHQ-style one-sided overshoot
        assert_eq!(m.saturated, 1);
        assert_eq!(m.raw_at(0, 0), 15);
        assert_eq!(m.raw_at(0, 1), 127 + m.center);
    }

    #[test]
    fn raw_f32_renders_poisoned_rows_as_nan() {
        let mut m = CodeMat::zeros(2, 2, center_for(15.0));
        m.store_raw(0, 0, 3.0);
        m.store_raw(0, 1, 7.0);
        m.poison_row(1);
        let f = m.raw_f32();
        assert_eq!(&f[..2], &[3.0, 7.0]);
        assert!(f[2].is_nan() && f[3].is_nan());
    }

    #[test]
    fn resize_resets_poison_and_saturation() {
        let mut m = CodeMat::zeros(2, 3, 8);
        m.poison_all();
        m.saturated = 5;
        m.resize(3, 2, 128);
        assert_eq!((m.rows, m.cols, m.center), (3, 2, 128));
        assert!(!m.any_poisoned());
        assert_eq!(m.saturated, 0);
        assert!(m.data.iter().all(|&c| c == 0));
    }

    #[test]
    fn scales_dequantize_per_tensor_and_per_row() {
        let mut s = CodeScales::default();
        s.resize_tensor();
        s.inv[0] = 0.5;
        s.zero[0] = 1.0;
        assert_eq!(s.deq(3, 4), 3.0); // row index ignored per-tensor
        s.resize_rows(2);
        s.inv = vec![0.5, 2.0];
        s.zero = vec![0.0, 1.0];
        assert_eq!(s.deq(0, 4), 2.0);
        assert_eq!(s.deq(1, 4), 9.0);
    }
}
