//! Stochastic rounding — the unbiased rounding primitive (paper §3.3).
//!
//! SR(x) = ceil(x) w.p. x - floor(x), else floor(x); implemented as
//! floor(x + u), u ~ U[0,1). E[SR(x)] = x and Var[SR(x)] = p(1-p) <= 1/4
//! (Proposition 4) — the 1/4 is what every variance bound in the paper
//! inherits its 1/(4B^2) factor from.

use crate::util::rng::Pcg32;

/// Stochastically round one value (already scaled to bin units).
#[inline]
pub fn sr(x: f32, rng: &mut Pcg32) -> f32 {
    (x + rng.uniform()).floor()
}

/// Stochastically round a slice in place, clipping codes to [0, nbins].
/// Reports clip/zero counts through the `sr` telemetry sink and returns
/// the number of codes the clamp actually moved.
pub fn sr_clip_slice(xs: &mut [f32], nbins: f32, rng: &mut Pcg32) -> u64 {
    let mut clipped = 0u64;
    let mut zeros = 0u64;
    for x in xs.iter_mut() {
        let raw = (*x + rng.uniform()).floor();
        let c = raw.clamp(0.0, nbins);
        clipped += u64::from(raw != c);
        zeros += u64::from(c == 0.0);
        *x = c;
    }
    crate::obs::quant::sr().record(&crate::quant::QuantStats {
        values: xs.len() as u64,
        clipped,
        zero_codes: zeros,
        ..Default::default()
    });
    clipped
}

/// Exact SR variance of a scaled tensor: sum over elements of p(1-p)
/// where p = frac(x). Used by tests and the Fig-3 variance analysis to
/// compare empirical variance against the closed form.
pub fn sr_exact_variance(scaled: &[f32]) -> f64 {
    scaled
        .iter()
        .map(|&t| {
            let p = f64::from(t) - f64::from(t.floor());
            p * (1.0 - p)
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unbiased_and_quarter_variance_at_half() {
        let mut rng = Pcg32::new(1, 2);
        let n = 200_000;
        let x = 3.5f32;
        let mut sum = 0.0f64;
        let mut sq = 0.0f64;
        for _ in 0..n {
            let v = f64::from(sr(x, &mut rng));
            sum += v;
            sq += v * v;
        }
        let mean = sum / f64::from(n);
        let var = sq / f64::from(n) - mean * mean;
        assert!((mean - 3.5).abs() < 0.005, "mean {mean}");
        assert!((var - 0.25).abs() < 0.005, "var {var}"); // p(1-p)=1/4
    }

    #[test]
    fn integers_are_exact() {
        let mut rng = Pcg32::new(2, 2);
        for x in [0.0f32, 1.0, 17.0, 255.0] {
            for _ in 0..100 {
                assert_eq!(sr(x, &mut rng), x);
            }
        }
    }

    #[test]
    fn exact_variance_formula_matches_empirical() {
        let scaled = vec![0.25f32, 1.9, 7.5, 3.0];
        let exact = sr_exact_variance(&scaled);
        let mut rng = Pcg32::new(9, 0);
        let reps = 100_000;
        let mut acc = 0.0f64;
        for _ in 0..reps {
            for &t in &scaled {
                let d = f64::from(sr(t, &mut rng)) - f64::from(t);
                acc += d * d;
            }
        }
        let emp = acc / f64::from(reps);
        assert!(
            (emp - exact).abs() < 0.01 * exact.max(0.1),
            "emp {emp} exact {exact}"
        );
    }

    #[test]
    fn clip_respects_bounds() {
        let mut rng = Pcg32::new(3, 1);
        let mut xs = vec![-0.4f32, 0.2, 254.9, 255.0, 300.0];
        sr_clip_slice(&mut xs, 255.0, &mut rng);
        for &v in &xs {
            assert!((0.0..=255.0).contains(&v), "{v}");
            assert_eq!(v.fract(), 0.0);
        }
    }

    /// Clip counting is exact on known out-of-range values: -1.5 rounds
    /// to -2 or -1 (always < 0) and 300.0 to 300 (always > 255) for any
    /// SR draw u in [0,1); 0.2 and 254.2 can never leave [0, 255].
    #[test]
    fn clip_count_exact_on_crafted_out_of_range_values() {
        for seed in 0..32u64 {
            let mut rng = Pcg32::new(seed, seed.wrapping_mul(7));
            let mut xs = vec![-1.5f32, 0.2, 300.0, 254.2];
            let clipped = sr_clip_slice(&mut xs, 255.0, &mut rng);
            assert_eq!(clipped, 2, "seed {seed}: {xs:?}");
            assert_eq!(xs[0], 0.0);
            assert_eq!(xs[2], 255.0);
        }
    }
}
