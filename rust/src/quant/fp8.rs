//! FP8 (E4M3) stochastic-rounding simulation — Table-2 comparison format
//! (stands in for Wang et al. '18 / HFP8-style 8-bit floating point).
//!
//! The tensor is scaled so its absmax lands on the format's max normal,
//! then each element is stochastically rounded to the FP8 grid: uniform
//! steps of 2^(e - man_bits) within a binade, subnormal step 2^(emin -
//! man_bits) near zero. Unbiased within range (floor+noise on the signed
//! grid), saturating at the top like real FP8 hardware.

use super::{Mat, EPS_RANGE};
use crate::util::rng::Pcg32;

pub const EXP_BITS: i32 = 4;
pub const MAN_BITS: i32 = 3;

pub fn max_normal() -> f32 {
    let bias = (1 << (EXP_BITS - 1)) - 1;
    let emax = (1 << EXP_BITS) - 2 - bias;
    2f32.powi(emax) * (2.0 - 2f32.powi(-MAN_BITS))
}

pub fn quantize(x: &Mat, rng: &mut Pcg32) -> Mat {
    let bias = (1 << (EXP_BITS - 1)) - 1;
    let emax = (1 << EXP_BITS) - 2 - bias;
    let emin = 1 - bias;
    let maxn = max_normal();

    let absmax = x
        .data
        .iter()
        .fold(0.0f32, |a, &v| a.max(v.abs()))
        .max(EPS_RANGE);
    let s = maxn / absmax;

    let mut out = Mat::zeros(x.rows, x.cols);
    let min_step = 2f32.powi(emin - MAN_BITS);
    for (o, &v) in out.data.iter_mut().zip(&x.data) {
        let xs = v * s;
        let ax = xs.abs().max(min_step);
        let e = ax.log2().floor().clamp(emin as f32, emax as f32);
        let step = 2f32.powf(e - MAN_BITS as f32);
        let q = ((xs / step + rng.uniform()).floor() * step).clamp(-maxn, maxn);
        *o = q / s;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e4m3_reserved_top_max_normal_is_240() {
        // We use the IEEE-style convention (top exponent reserved), so
        // max normal is 2^7 * 1.875 = 240 — not OCP-E4M3's 448, which
        // reclaims the top binade. Both sides (Rust here and
        // python/compile/quantizers.py::fp8_sim) share this convention.
        assert_eq!(max_normal(), 240.0);
    }

    #[test]
    fn grid_points_fixed() {
        // representable values are reproduced exactly (they sit on the
        // grid so floor(x/step + u) == x/step deterministically).
        let vals = vec![1.0f32, 1.125, 0.5, -2.0, 240.0, -240.0];
        let x = Mat::from_vec(1, vals.len(), vals.clone());
        let mut rng = Pcg32::new(3, 3);
        // absmax=240 -> s=1 -> grid preserved
        for _ in 0..50 {
            let q = quantize(&x, &mut rng);
            for (a, b) in q.data.iter().zip(&vals) {
                assert_eq!(a, b);
            }
        }
    }

    #[test]
    fn unbiased_midpoint() {
        // x halfway between two grid points must average to x.
        let x = Mat::from_vec(1, 1, vec![1.0625f32 * 64.0]); // mid-binade at scale
        let mut rng = Pcg32::new(5, 5);
        let reps = 60_000;
        let mut sum = 0.0f64;
        for _ in 0..reps {
            sum += f64::from(quantize(&x, &mut rng).data[0]);
        }
        let mean = sum / f64::from(reps);
        let rel = (mean - f64::from(x.data[0])).abs() / f64::from(x.data[0]);
        assert!(rel < 2e-3, "rel bias {rel}");
    }

    #[test]
    fn relative_error_bounded_by_mantissa_step() {
        let mut rng = Pcg32::new(7, 7);
        let mut x = Mat::zeros(4, 64);
        for v in &mut x.data {
            *v = rng.normal();
        }
        let q = quantize(&x, &mut rng);
        let absmax = x.data.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        for (&qv, &xv) in q.data.iter().zip(&x.data) {
            // error <= one grid step at that magnitude (after scaling)
            let scale = max_normal() / absmax;
            let ax = (xv * scale).abs().max(2f32.powi(-9));
            let step = 2f32.powf(ax.log2().floor() - MAN_BITS as f32) / scale;
            assert!((qv - xv).abs() <= step * 1.01, "{qv} vs {xv} step {step}");
        }
    }
}
