//! Per-tensor quantizer (paper §3.3) — the INT8-training baseline
//! [Banner et al. '18, Zhu et al. '20].
//!
//! One scale S = B / R(X) and one zero point Z = min(X) for the whole
//! tensor. Variance bound (Eq. 9): Var <= N*D/(4B^2) * R(X)^2 — a single
//! outlier row inflates the bin size for *every* row, which is exactly
//! the failure mode PSQ/BHQ repair.

use super::codes;
use super::{CodeMat, CodeScales, Mat, QuantStats, Quantized, EPS_RANGE, MAX_SCALE};
use crate::quant::sr;
use crate::util::rng::Pcg32;

/// Stochastic PTQ quantize-dequantize with `nbins` = B bins. NaN input
/// returns a fully NaN-poisoned output (see [`super::poisoned`]): the
/// `.max(EPS_RANGE)` floor would otherwise swallow a NaN range.
pub fn quantize(x: &Mat, nbins: f32, rng: &mut Pcg32) -> Quantized {
    let tel = crate::obs::quant::ptq();
    let (q, st) = quantize_stats(x, nbins, rng, tel.should_sample());
    tel.record(&st);
    q
}

/// [`quantize`] plus per-call telemetry. Consumes the same RNG draws as
/// the untracked path — determinism-given-seed is unaffected. The exact
/// SR variance sum p(1-p)/scale^2 is computed only when
/// `sample_variance` (it costs an extra f64 op per element).
pub fn quantize_stats(
    x: &Mat,
    nbins: f32,
    rng: &mut Pcg32,
    sample_variance: bool,
) -> (Quantized, QuantStats) {
    let mut st = QuantStats::default();
    let (lo, hi) = x.minmax();
    if (hi - lo).is_nan() {
        st.poisoned_rows = x.rows as u64;
        return (super::poisoned(x.rows, x.cols, nbins), st);
    }
    let range = (hi - lo).max(EPS_RANGE);
    let scale = (nbins / range).min(MAX_SCALE);
    let mut codes = CodeMat::zeros(x.rows, x.cols, codes::center_for(nbins));
    let center = codes.center;
    let mut saturated = 0u64;
    let mut deq = Mat::zeros(x.rows, x.cols);
    let mut pvar = 0.0f64;
    for ((c, d), &v) in codes
        .data
        .iter_mut()
        .zip(deq.data.iter_mut())
        .zip(&x.data)
    {
        let t = scale * (v - lo);
        let raw = sr::sr(t, rng);
        let q = raw.clamp(0.0, nbins);
        st.clipped += u64::from(raw != q);
        st.zero_codes += u64::from(q == 0.0);
        if sample_variance {
            let p = f64::from(t) - f64::from(t.floor());
            pvar += p * (1.0 - p);
        }
        let (s, moved) = codes::center_code(q, center);
        *c = s;
        saturated += u64::from(moved);
        *d = q / scale + lo;
    }
    codes.saturated = saturated;
    st.values = x.data.len() as u64;
    if sample_variance {
        st.sr_variance = Some(pvar / f64::from(scale).powi(2));
    }
    (
        Quantized {
            codes,
            deq,
            row_bin_size: vec![1.0 / scale; x.rows],
        },
        st,
    )
}

/// Fused quantize-dequantize into a caller-owned buffer: one pass, no
/// codes matrix, no output allocation once `out` has warmed up to shape
/// (the native executor's zero-allocation step path). Bitwise identical
/// to `quantize(x, nbins, rng).deq` — same scale/zero math, same RNG
/// draw order, same telemetry cadence.
pub fn apply_into(x: &Mat, nbins: f32, rng: &mut Pcg32, out: &mut Mat) {
    let tel = crate::obs::quant::ptq();
    let sample_variance = tel.should_sample();
    let mut st = QuantStats::default();
    out.resize(x.rows, x.cols);
    let (lo, hi) = x.minmax();
    if (hi - lo).is_nan() {
        st.poisoned_rows = x.rows as u64;
        out.data.fill(f32::NAN);
        tel.record(&st);
        return;
    }
    let range = (hi - lo).max(EPS_RANGE);
    let scale = (nbins / range).min(MAX_SCALE);
    let mut pvar = 0.0f64;
    for (d, &v) in out.data.iter_mut().zip(&x.data) {
        let t = scale * (v - lo);
        let raw = sr::sr(t, rng);
        let q = raw.clamp(0.0, nbins);
        st.clipped += u64::from(raw != q);
        st.zero_codes += u64::from(q == 0.0);
        if sample_variance {
            let p = f64::from(t) - f64::from(t.floor());
            pvar += p * (1.0 - p);
        }
        *d = q / scale + lo;
    }
    st.values = x.data.len() as u64;
    if sample_variance {
        st.sr_variance = Some(pvar / f64::from(scale).powi(2));
    }
    tel.record(&st);
}

/// Integer-code hot path: same scale/zero math, RNG draw order and
/// telemetry cadence as [`apply_into`], but emits centered i8 codes plus
/// a per-tensor [`CodeScales`] and never materializes the dequantized
/// f32 matrix — the input to `kernels::gemm_i8`. Requires integral
/// `nbins <= 255` (the `GradQuantizer::supports_codes` gate), under
/// which the post-clamp code range [0, B] can never saturate i8.
pub fn quantize_codes_into(
    x: &Mat,
    nbins: f32,
    rng: &mut Pcg32,
    codes: &mut CodeMat,
    scales: &mut CodeScales,
) {
    let tel = crate::obs::quant::ptq();
    let sample_variance = tel.should_sample();
    let mut st = QuantStats::default();
    codes.resize(x.rows, x.cols, codes::center_for(nbins));
    scales.resize_tensor();
    let (lo, hi) = x.minmax();
    if (hi - lo).is_nan() {
        st.poisoned_rows = x.rows as u64;
        codes.poison_all();
        scales.inv[0] = f32::NAN;
        scales.zero[0] = f32::NAN;
        tel.record(&st);
        return;
    }
    let range = (hi - lo).max(EPS_RANGE);
    let scale = (nbins / range).min(MAX_SCALE);
    let center = codes.center;
    let mut pvar = 0.0f64;
    for (c, &v) in codes.data.iter_mut().zip(&x.data) {
        let t = scale * (v - lo);
        let raw = sr::sr(t, rng);
        let q = raw.clamp(0.0, nbins);
        st.clipped += u64::from(raw != q);
        st.zero_codes += u64::from(q == 0.0);
        if sample_variance {
            let p = f64::from(t) - f64::from(t.floor());
            pvar += p * (1.0 - p);
        }
        *c = codes::center_code(q, center).0;
    }
    st.values = x.data.len() as u64;
    if sample_variance {
        st.sr_variance = Some(pvar / f64::from(scale).powi(2));
    }
    scales.inv[0] = 1.0 / scale;
    scales.zero[0] = lo + center as f32 / scale;
    tel.record(&st);
}

/// Deterministic round-to-nearest operand codes: [`quantize_det`]'s
/// math on a raw row-major slice, emitting centered i8 codes plus a
/// per-tensor [`CodeScales`] — no RNG, no telemetry. This quantizes the
/// *non-gradient* GEMM operands (activations, inputs, weights) feeding
/// the integer backward kernels, where the paper's unbiasedness
/// requirement applies to the gradient signal only, so round-to-nearest
/// (lower variance than SR) is the right choice.
pub fn quantize_det_codes_into(
    x: &[f32],
    rows: usize,
    cols: usize,
    nbins: f32,
    codes: &mut CodeMat,
    scales: &mut CodeScales,
) {
    debug_assert_eq!(x.len(), rows * cols);
    codes.resize(rows, cols, codes::center_for(nbins));
    scales.resize_tensor();
    let (lo, hi) = super::tensor::minmax_slice(x);
    if (hi - lo).is_nan() {
        codes.poison_all();
        scales.inv[0] = f32::NAN;
        scales.zero[0] = f32::NAN;
        return;
    }
    let range = (hi - lo).max(EPS_RANGE);
    let scale = (nbins / range).min(MAX_SCALE);
    let center = codes.center;
    for (c, &v) in codes.data.iter_mut().zip(x) {
        let q = (scale * (v - lo)).round().clamp(0.0, nbins);
        *c = codes::center_code(q, center).0;
    }
    scales.inv[0] = 1.0 / scale;
    scales.zero[0] = lo + center as f32 / scale;
}

/// Deterministic round-to-nearest PTQ (the forward-path Q_f / Q_theta).
pub fn quantize_det(x: &Mat, nbins: f32) -> Mat {
    let (lo, hi) = x.minmax();
    let range = (hi - lo).max(EPS_RANGE);
    let scale = (nbins / range).min(MAX_SCALE);
    let mut deq = Mat::zeros(x.rows, x.cols);
    for (d, &v) in deq.data.iter_mut().zip(&x.data) {
        let q = (scale * (v - lo)).round().clamp(0.0, nbins);
        *d = q / scale + lo;
    }
    deq
}

/// Eq. (9) upper bound: N*D/(4B^2) * R(X)^2.
pub fn variance_bound(x: &Mat, nbins: f32) -> f64 {
    let (lo, hi) = x.minmax();
    let r = f64::from(hi - lo);
    (x.rows * x.cols) as f64 / (4.0 * f64::from(nbins).powi(2)) * r * r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_in_range_and_reconstruction_close() {
        let mut rng = Pcg32::new(4, 4);
        let mut x = Mat::zeros(8, 16);
        for v in &mut x.data {
            *v = rng.normal();
        }
        let b = 255.0;
        let q = quantize(&x, b, &mut rng);
        assert_eq!(q.codes.saturated, 0);
        for i in 0..q.codes.rows {
            for j in 0..q.codes.cols {
                assert!((0..=b as i32).contains(&q.codes.raw_at(i, j)));
            }
        }
        // |deq - x| <= bin size elementwise (SR moves at most one bin)
        let bin = q.row_bin_size[0];
        for (&d, &v) in q.deq.data.iter().zip(&x.data) {
            assert!((d - v).abs() <= bin * 1.001, "{d} vs {v} bin {bin}");
        }
    }

    #[test]
    fn empirical_variance_below_bound() {
        let mut rng = Pcg32::new(8, 8);
        let mut x = Mat::zeros(4, 32);
        for v in &mut x.data {
            *v = rng.normal();
        }
        let b = 15.0; // 4-bit
        let bound = variance_bound(&x, b);
        let reps = 500;
        let mut acc = 0.0;
        for _ in 0..reps {
            acc += quantize(&x, b, &mut rng).deq.sq_err(&x);
        }
        let emp = acc / f64::from(reps);
        assert!(emp <= bound, "emp {emp} bound {bound}");
    }

    #[test]
    fn det_is_deterministic_and_within_half_bin() {
        let x = Mat::from_vec(2, 3, vec![0.0, 0.3, 1.0, -1.0, 0.5, 0.9]);
        let a = quantize_det(&x, 255.0);
        let b = quantize_det(&x, 255.0);
        assert_eq!(a, b);
        let bin = 2.0 / 255.0; // range = 2
        for (&d, &v) in a.data.iter().zip(&x.data) {
            assert!((d - v).abs() <= bin / 2.0 + 1e-6);
        }
    }

    #[test]
    fn nan_input_poisons_output() {
        let x = Mat::from_vec(2, 2, vec![1.0, f32::NAN, 0.5, -0.5]);
        let mut rng = Pcg32::new(3, 3);
        let q = quantize(&x, 15.0, &mut rng);
        assert!(q.deq.data.iter().all(|v| v.is_nan()));
        assert!(q.codes.poisoned.iter().all(|&p| p));
        assert!(q.codes.raw_f32().iter().all(|v| v.is_nan()));
        assert!(q.row_bin_size.iter().all(|v| v.is_nan()));
    }

    #[test]
    fn stats_count_zero_codes_exactly() {
        // t = 15*(v - 0): sr(0) = floor(u) = 0 always, sr(15) = 15 always
        // (u < 1) — so exactly three zero codes and no clips, regardless
        // of the SR draws.
        let x = Mat::from_vec(2, 2, vec![0.0, 0.0, 0.0, 1.0]);
        let mut rng = Pcg32::new(9, 9);
        let (q, st) = quantize_stats(&x, 15.0, &mut rng, true);
        assert_eq!(st.values, 4);
        assert_eq!(st.zero_codes, 3);
        assert_eq!(st.clipped, 0);
        assert_eq!(st.poisoned_rows, 0);
        // p = 0 at every point => exact SR variance 0
        assert_eq!(st.sr_variance, Some(0.0));
        assert_eq!(q.codes.raw_f32(), vec![0.0, 0.0, 0.0, 15.0]);
    }

    /// `quantize_codes_into` consumes the identical RNG stream as
    /// `quantize_stats` and emits the same raw codes; its per-tensor
    /// scales reconstruct the same affine map the deq path uses.
    #[test]
    fn codes_path_matches_stats_path() {
        let mut x = Mat::zeros(5, 7);
        let mut rng0 = Pcg32::new(17, 3);
        for v in &mut x.data {
            *v = rng0.normal();
        }
        let mut ra = Pcg32::new(41, 6);
        let mut rb = Pcg32::new(41, 6);
        let (q, _) = quantize_stats(&x, 15.0, &mut ra, false);
        let mut codes = CodeMat::default();
        let mut scales = CodeScales::default();
        quantize_codes_into(&x, 15.0, &mut rb, &mut codes, &mut scales);
        assert_eq!(ra.uniform(), rb.uniform(), "rng streams diverged");
        assert_eq!(q.codes.data, codes.data);
        assert_eq!(q.codes.center, codes.center);
        assert!(!scales.per_row);
        // scales reconstruct the deq values up to f32 rounding
        for i in 0..codes.rows {
            for (j, &c) in codes.row(i).iter().enumerate() {
                let rec = scales.deq(i, c);
                let d = q.deq.data[i * q.deq.cols + j];
                assert!((rec - d).abs() <= 1e-6 * d.abs().max(1.0));
            }
        }
    }

    /// NaN input poisons the codes path: mask set, NaN scales.
    #[test]
    fn codes_path_poisons_on_nan() {
        let x = Mat::from_vec(2, 2, vec![1.0, f32::NAN, 0.5, -0.5]);
        let mut rng = Pcg32::new(3, 3);
        let mut codes = CodeMat::default();
        let mut scales = CodeScales::default();
        quantize_codes_into(&x, 15.0, &mut rng, &mut codes, &mut scales);
        assert!(codes.poisoned.iter().all(|&p| p));
        assert!(scales.inv[0].is_nan() && scales.zero[0].is_nan());
    }

    #[test]
    fn stats_path_consumes_identical_rng_draws() {
        let mut x = Mat::zeros(4, 8);
        let mut rng0 = Pcg32::new(11, 2);
        for v in &mut x.data {
            *v = rng0.normal();
        }
        let mut ra = Pcg32::new(21, 4);
        let mut rb = Pcg32::new(21, 4);
        let qa = quantize_stats(&x, 15.0, &mut ra, true).0;
        let qb = quantize_stats(&x, 15.0, &mut rb, false).0;
        assert_eq!(qa.deq, qb.deq);
        assert_eq!(ra.uniform(), rb.uniform(), "rng streams diverged");
    }

    #[test]
    fn nan_input_reports_poisoned_rows() {
        let x = Mat::from_vec(2, 2, vec![1.0, f32::NAN, 0.5, -0.5]);
        let mut rng = Pcg32::new(3, 3);
        let (_, st) = quantize_stats(&x, 15.0, &mut rng, true);
        assert_eq!(st.poisoned_rows, 2);
        assert_eq!(st.values, 0);
    }

    #[test]
    fn constant_tensor_is_exact() {
        let x = Mat::from_vec(3, 3, vec![2.5; 9]);
        let mut rng = Pcg32::new(1, 1);
        let q = quantize(&x, 15.0, &mut rng);
        for &d in &q.deq.data {
            assert!((d - 2.5).abs() < 1e-6);
        }
    }

    /// code*inv + zero reconstructs [`quantize_det`]'s q/scale + lo:
    /// ULP-level close in general (the rewrite reassociates), exactly
    /// equal when the scale is a power of two.
    #[test]
    fn det_codes_reconstruct_quantize_det() {
        let mut rng = Pcg32::new(9, 9);
        let mut x = Mat::zeros(6, 7);
        for v in &mut x.data {
            *v = rng.normal();
        }
        let mut codes = CodeMat::default();
        let mut scales = CodeScales::default();
        quantize_det_codes_into(&x.data, 6, 7, 255.0, &mut codes, &mut scales);
        let det = quantize_det(&x, 255.0);
        for (idx, &d) in det.data.iter().enumerate() {
            let rec = codes.data[idx] as f32 * scales.inv[0] + scales.zero[0];
            assert!(
                (rec - d).abs() <= 1e-5 * d.abs().max(1.0),
                "idx {idx}: {rec} vs {d}"
            );
        }

        // power-of-two grid: lo = 0, range = 255/128, so scale = 128
        // exactly; reconstruction is bitwise.
        let mut px = Mat::zeros(1, 256);
        for (i, v) in px.data.iter_mut().enumerate() {
            *v = i as f32 / 128.0;
        }
        quantize_det_codes_into(&px.data, 1, 256, 255.0, &mut codes, &mut scales);
        let pdet = quantize_det(&px, 255.0);
        for (idx, &d) in pdet.data.iter().enumerate() {
            let rec = codes.data[idx] as f32 * scales.inv[0] + scales.zero[0];
            assert_eq!(rec.to_bits(), d.to_bits(), "idx {idx}");
        }
    }

    #[test]
    fn det_codes_poison_on_nan_and_handle_empty() {
        let mut codes = CodeMat::default();
        let mut scales = CodeScales::default();
        quantize_det_codes_into(&[1.0, f32::NAN], 1, 2, 255.0, &mut codes, &mut scales);
        assert!(codes.poisoned.iter().all(|&p| p));
        assert!(scales.inv[0].is_nan() && scales.zero[0].is_nan());
        quantize_det_codes_into(&[], 0, 0, 255.0, &mut codes, &mut scales);
        assert_eq!(codes.len(), 0);
        assert!(scales.inv[0].is_finite());
    }
}
