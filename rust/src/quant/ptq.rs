//! Per-tensor quantizer (paper §3.3) — the INT8-training baseline
//! [Banner et al. '18, Zhu et al. '20].
//!
//! One scale S = B / R(X) and one zero point Z = min(X) for the whole
//! tensor. Variance bound (Eq. 9): Var <= N*D/(4B^2) * R(X)^2 — a single
//! outlier row inflates the bin size for *every* row, which is exactly
//! the failure mode PSQ/BHQ repair.

use super::{Mat, QuantStats, Quantized, EPS_RANGE, MAX_SCALE};
use crate::quant::sr;
use crate::util::rng::Pcg32;

/// Stochastic PTQ quantize-dequantize with `nbins` = B bins. NaN input
/// returns a fully NaN-poisoned output (see [`super::poisoned`]): the
/// `.max(EPS_RANGE)` floor would otherwise swallow a NaN range.
pub fn quantize(x: &Mat, nbins: f32, rng: &mut Pcg32) -> Quantized {
    let tel = crate::obs::quant::ptq();
    let (q, st) = quantize_stats(x, nbins, rng, tel.should_sample());
    tel.record(&st);
    q
}

/// [`quantize`] plus per-call telemetry. Consumes the same RNG draws as
/// the untracked path — determinism-given-seed is unaffected. The exact
/// SR variance sum p(1-p)/scale^2 is computed only when
/// `sample_variance` (it costs an extra f64 op per element).
pub fn quantize_stats(
    x: &Mat,
    nbins: f32,
    rng: &mut Pcg32,
    sample_variance: bool,
) -> (Quantized, QuantStats) {
    let mut st = QuantStats::default();
    let (lo, hi) = x.minmax();
    if (hi - lo).is_nan() {
        st.poisoned_rows = x.rows as u64;
        return (super::poisoned(x.rows, x.cols), st);
    }
    let range = (hi - lo).max(EPS_RANGE);
    let scale = (nbins / range).min(MAX_SCALE);
    let mut codes = Mat::zeros(x.rows, x.cols);
    let mut deq = Mat::zeros(x.rows, x.cols);
    let mut pvar = 0.0f64;
    for ((c, d), &v) in codes
        .data
        .iter_mut()
        .zip(deq.data.iter_mut())
        .zip(&x.data)
    {
        let t = scale * (v - lo);
        let raw = sr::sr(t, rng);
        let q = raw.clamp(0.0, nbins);
        st.clipped += u64::from(raw != q);
        st.zero_codes += u64::from(q == 0.0);
        if sample_variance {
            let p = f64::from(t) - f64::from(t.floor());
            pvar += p * (1.0 - p);
        }
        *c = q;
        *d = q / scale + lo;
    }
    st.values = x.data.len() as u64;
    if sample_variance {
        st.sr_variance = Some(pvar / f64::from(scale).powi(2));
    }
    (
        Quantized {
            codes,
            deq,
            row_bin_size: vec![1.0 / scale; x.rows],
        },
        st,
    )
}

/// Fused quantize-dequantize into a caller-owned buffer: one pass, no
/// codes matrix, no output allocation once `out` has warmed up to shape
/// (the native executor's zero-allocation step path). Bitwise identical
/// to `quantize(x, nbins, rng).deq` — same scale/zero math, same RNG
/// draw order, same telemetry cadence.
pub fn apply_into(x: &Mat, nbins: f32, rng: &mut Pcg32, out: &mut Mat) {
    let tel = crate::obs::quant::ptq();
    let sample_variance = tel.should_sample();
    let mut st = QuantStats::default();
    out.resize(x.rows, x.cols);
    let (lo, hi) = x.minmax();
    if (hi - lo).is_nan() {
        st.poisoned_rows = x.rows as u64;
        out.data.fill(f32::NAN);
        tel.record(&st);
        return;
    }
    let range = (hi - lo).max(EPS_RANGE);
    let scale = (nbins / range).min(MAX_SCALE);
    let mut pvar = 0.0f64;
    for (d, &v) in out.data.iter_mut().zip(&x.data) {
        let t = scale * (v - lo);
        let raw = sr::sr(t, rng);
        let q = raw.clamp(0.0, nbins);
        st.clipped += u64::from(raw != q);
        st.zero_codes += u64::from(q == 0.0);
        if sample_variance {
            let p = f64::from(t) - f64::from(t.floor());
            pvar += p * (1.0 - p);
        }
        *d = q / scale + lo;
    }
    st.values = x.data.len() as u64;
    if sample_variance {
        st.sr_variance = Some(pvar / f64::from(scale).powi(2));
    }
    tel.record(&st);
}

/// Deterministic round-to-nearest PTQ (the forward-path Q_f / Q_theta).
pub fn quantize_det(x: &Mat, nbins: f32) -> Mat {
    let (lo, hi) = x.minmax();
    let range = (hi - lo).max(EPS_RANGE);
    let scale = (nbins / range).min(MAX_SCALE);
    let mut deq = Mat::zeros(x.rows, x.cols);
    for (d, &v) in deq.data.iter_mut().zip(&x.data) {
        let q = (scale * (v - lo)).round().clamp(0.0, nbins);
        *d = q / scale + lo;
    }
    deq
}

/// Eq. (9) upper bound: N*D/(4B^2) * R(X)^2.
pub fn variance_bound(x: &Mat, nbins: f32) -> f64 {
    let (lo, hi) = x.minmax();
    let r = f64::from(hi - lo);
    (x.rows * x.cols) as f64 / (4.0 * f64::from(nbins).powi(2)) * r * r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_in_range_and_reconstruction_close() {
        let mut rng = Pcg32::new(4, 4);
        let mut x = Mat::zeros(8, 16);
        for v in &mut x.data {
            *v = rng.normal();
        }
        let b = 255.0;
        let q = quantize(&x, b, &mut rng);
        for &c in &q.codes.data {
            assert!((0.0..=b).contains(&c) && c.fract() == 0.0);
        }
        // |deq - x| <= bin size elementwise (SR moves at most one bin)
        let bin = q.row_bin_size[0];
        for (&d, &v) in q.deq.data.iter().zip(&x.data) {
            assert!((d - v).abs() <= bin * 1.001, "{d} vs {v} bin {bin}");
        }
    }

    #[test]
    fn empirical_variance_below_bound() {
        let mut rng = Pcg32::new(8, 8);
        let mut x = Mat::zeros(4, 32);
        for v in &mut x.data {
            *v = rng.normal();
        }
        let b = 15.0; // 4-bit
        let bound = variance_bound(&x, b);
        let reps = 500;
        let mut acc = 0.0;
        for _ in 0..reps {
            acc += quantize(&x, b, &mut rng).deq.sq_err(&x);
        }
        let emp = acc / f64::from(reps);
        assert!(emp <= bound, "emp {emp} bound {bound}");
    }

    #[test]
    fn det_is_deterministic_and_within_half_bin() {
        let x = Mat::from_vec(2, 3, vec![0.0, 0.3, 1.0, -1.0, 0.5, 0.9]);
        let a = quantize_det(&x, 255.0);
        let b = quantize_det(&x, 255.0);
        assert_eq!(a, b);
        let bin = 2.0 / 255.0; // range = 2
        for (&d, &v) in a.data.iter().zip(&x.data) {
            assert!((d - v).abs() <= bin / 2.0 + 1e-6);
        }
    }

    #[test]
    fn nan_input_poisons_output() {
        let x = Mat::from_vec(2, 2, vec![1.0, f32::NAN, 0.5, -0.5]);
        let mut rng = Pcg32::new(3, 3);
        let q = quantize(&x, 15.0, &mut rng);
        assert!(q.deq.data.iter().all(|v| v.is_nan()));
        assert!(q.codes.data.iter().all(|v| v.is_nan()));
        assert!(q.row_bin_size.iter().all(|v| v.is_nan()));
    }

    #[test]
    fn stats_count_zero_codes_exactly() {
        // t = 15*(v - 0): sr(0) = floor(u) = 0 always, sr(15) = 15 always
        // (u < 1) — so exactly three zero codes and no clips, regardless
        // of the SR draws.
        let x = Mat::from_vec(2, 2, vec![0.0, 0.0, 0.0, 1.0]);
        let mut rng = Pcg32::new(9, 9);
        let (q, st) = quantize_stats(&x, 15.0, &mut rng, true);
        assert_eq!(st.values, 4);
        assert_eq!(st.zero_codes, 3);
        assert_eq!(st.clipped, 0);
        assert_eq!(st.poisoned_rows, 0);
        // p = 0 at every point => exact SR variance 0
        assert_eq!(st.sr_variance, Some(0.0));
        assert_eq!(q.codes.data, vec![0.0, 0.0, 0.0, 15.0]);
    }

    #[test]
    fn stats_path_consumes_identical_rng_draws() {
        let mut x = Mat::zeros(4, 8);
        let mut rng0 = Pcg32::new(11, 2);
        for v in &mut x.data {
            *v = rng0.normal();
        }
        let mut ra = Pcg32::new(21, 4);
        let mut rb = Pcg32::new(21, 4);
        let qa = quantize_stats(&x, 15.0, &mut ra, true).0;
        let qb = quantize_stats(&x, 15.0, &mut rb, false).0;
        assert_eq!(qa.deq, qb.deq);
        assert_eq!(ra.uniform(), rb.uniform(), "rng streams diverged");
    }

    #[test]
    fn nan_input_reports_poisoned_rows() {
        let x = Mat::from_vec(2, 2, vec![1.0, f32::NAN, 0.5, -0.5]);
        let mut rng = Pcg32::new(3, 3);
        let (_, st) = quantize_stats(&x, 15.0, &mut rng, true);
        assert_eq!(st.poisoned_rows, 2);
        assert_eq!(st.values, 0);
    }

    #[test]
    fn constant_tensor_is_exact() {
        let x = Mat::from_vec(3, 3, vec![2.5; 9]);
        let mut rng = Pcg32::new(1, 1);
        let q = quantize(&x, 15.0, &mut rng);
        for &d in &q.deq.data {
            assert!((d - 2.5).abs() < 1e-6);
        }
    }
}
