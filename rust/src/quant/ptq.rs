//! Per-tensor quantizer (paper §3.3) — the INT8-training baseline
//! [Banner et al. '18, Zhu et al. '20].
//!
//! One scale S = B / R(X) and one zero point Z = min(X) for the whole
//! tensor. Variance bound (Eq. 9): Var <= N*D/(4B^2) * R(X)^2 — a single
//! outlier row inflates the bin size for *every* row, which is exactly
//! the failure mode PSQ/BHQ repair.

use super::{Mat, Quantized, EPS_RANGE, MAX_SCALE};
use crate::quant::sr;
use crate::util::rng::Pcg32;

/// Stochastic PTQ quantize-dequantize with `nbins` = B bins. NaN input
/// returns a fully NaN-poisoned output (see [`super::poisoned`]): the
/// `.max(EPS_RANGE)` floor would otherwise swallow a NaN range.
pub fn quantize(x: &Mat, nbins: f32, rng: &mut Pcg32) -> Quantized {
    let (lo, hi) = x.minmax();
    if (hi - lo).is_nan() {
        return super::poisoned(x.rows, x.cols);
    }
    let range = (hi - lo).max(EPS_RANGE);
    let scale = (nbins / range).min(MAX_SCALE);
    let mut codes = Mat::zeros(x.rows, x.cols);
    let mut deq = Mat::zeros(x.rows, x.cols);
    for ((c, d), &v) in codes
        .data
        .iter_mut()
        .zip(deq.data.iter_mut())
        .zip(&x.data)
    {
        let t = scale * (v - lo);
        let q = sr::sr(t, rng).clamp(0.0, nbins);
        *c = q;
        *d = q / scale + lo;
    }
    Quantized {
        codes,
        deq,
        row_bin_size: vec![1.0 / scale; x.rows],
    }
}

/// Deterministic round-to-nearest PTQ (the forward-path Q_f / Q_theta).
pub fn quantize_det(x: &Mat, nbins: f32) -> Mat {
    let (lo, hi) = x.minmax();
    let range = (hi - lo).max(EPS_RANGE);
    let scale = (nbins / range).min(MAX_SCALE);
    let mut deq = Mat::zeros(x.rows, x.cols);
    for (d, &v) in deq.data.iter_mut().zip(&x.data) {
        let q = (scale * (v - lo)).round().clamp(0.0, nbins);
        *d = q / scale + lo;
    }
    deq
}

/// Eq. (9) upper bound: N*D/(4B^2) * R(X)^2.
pub fn variance_bound(x: &Mat, nbins: f32) -> f64 {
    let (lo, hi) = x.minmax();
    let r = f64::from(hi - lo);
    (x.rows * x.cols) as f64 / (4.0 * f64::from(nbins).powi(2)) * r * r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_in_range_and_reconstruction_close() {
        let mut rng = Pcg32::new(4, 4);
        let mut x = Mat::zeros(8, 16);
        for v in &mut x.data {
            *v = rng.normal();
        }
        let b = 255.0;
        let q = quantize(&x, b, &mut rng);
        for &c in &q.codes.data {
            assert!((0.0..=b).contains(&c) && c.fract() == 0.0);
        }
        // |deq - x| <= bin size elementwise (SR moves at most one bin)
        let bin = q.row_bin_size[0];
        for (&d, &v) in q.deq.data.iter().zip(&x.data) {
            assert!((d - v).abs() <= bin * 1.001, "{d} vs {v} bin {bin}");
        }
    }

    #[test]
    fn empirical_variance_below_bound() {
        let mut rng = Pcg32::new(8, 8);
        let mut x = Mat::zeros(4, 32);
        for v in &mut x.data {
            *v = rng.normal();
        }
        let b = 15.0; // 4-bit
        let bound = variance_bound(&x, b);
        let reps = 500;
        let mut acc = 0.0;
        for _ in 0..reps {
            acc += quantize(&x, b, &mut rng).deq.sq_err(&x);
        }
        let emp = acc / f64::from(reps);
        assert!(emp <= bound, "emp {emp} bound {bound}");
    }

    #[test]
    fn det_is_deterministic_and_within_half_bin() {
        let x = Mat::from_vec(2, 3, vec![0.0, 0.3, 1.0, -1.0, 0.5, 0.9]);
        let a = quantize_det(&x, 255.0);
        let b = quantize_det(&x, 255.0);
        assert_eq!(a, b);
        let bin = 2.0 / 255.0; // range = 2
        for (&d, &v) in a.data.iter().zip(&x.data) {
            assert!((d - v).abs() <= bin / 2.0 + 1e-6);
        }
    }

    #[test]
    fn nan_input_poisons_output() {
        let x = Mat::from_vec(2, 2, vec![1.0, f32::NAN, 0.5, -0.5]);
        let mut rng = Pcg32::new(3, 3);
        let q = quantize(&x, 15.0, &mut rng);
        assert!(q.deq.data.iter().all(|v| v.is_nan()));
        assert!(q.codes.data.iter().all(|v| v.is_nan()));
        assert!(q.row_bin_size.iter().all(|v| v.is_nan()));
    }

    #[test]
    fn constant_tensor_is_exact() {
        let x = Mat::from_vec(3, 3, vec![2.5; 9]);
        let mut rng = Pcg32::new(1, 1);
        let q = quantize(&x, 15.0, &mut rng);
        for &d in &q.deq.data {
            assert!((d - 2.5).abs() < 1e-6);
        }
    }
}
