//! Per-sample quantizer (paper §4.1).
//!
//! Scale matrix S = diag(s_1..s_N) with s_i = B / R(x_i), zero point
//! z_i = min(x_i): every sample (row) gets its own affine map, so a
//! correctly-classified sample with near-zero gradient range gets tiny
//! bins instead of inheriting the batch outlier's huge ones. Variance
//! bound: D/(4B^2) * sum_i R(x_i)^2 <= the PTQ bound (Eq. 9) since
//! R(X) = max_i R(x_i). O(N*D) FP32 overhead, same as FBGEMM's row-wise
//! path.

use super::codes;
use super::{CodeMat, CodeScales, Mat, QuantStats, Quantized, EPS_RANGE, MAX_SCALE};
use crate::quant::sr;
use crate::util::rng::Pcg32;

pub fn quantize(x: &Mat, nbins: f32, rng: &mut Pcg32) -> Quantized {
    let tel = crate::obs::quant::psq();
    let (q, st) = quantize_stats(x, nbins, rng, tel.should_sample());
    tel.record(&st);
    q
}

/// [`quantize`] plus per-call telemetry; identical RNG draw order. The
/// exact SR variance sum p(1-p)/scale_i^2 is computed only when
/// `sample_variance`.
pub fn quantize_stats(
    x: &Mat,
    nbins: f32,
    rng: &mut Pcg32,
    sample_variance: bool,
) -> (Quantized, QuantStats) {
    let mut st = QuantStats::default();
    let mm = x.row_minmax();
    let mut codes = CodeMat::zeros(x.rows, x.cols, codes::center_for(nbins));
    let center = codes.center;
    let mut saturated = 0u64;
    let mut deq = Mat::zeros(x.rows, x.cols);
    let mut bins = Vec::with_capacity(x.rows);
    let mut pvar = 0.0f64;
    for i in 0..x.rows {
        let (lo, hi) = mm[i];
        // NaN row: poison that row only (clean rows are still usable —
        // the per-sample axis isolates a diverged sample's gradient).
        if (hi - lo).is_nan() {
            st.poisoned_rows += 1;
            bins.push(f32::NAN);
            codes.poison_row(i);
            for d in deq.row_mut(i) {
                *d = f32::NAN;
            }
            continue;
        }
        let range = (hi - lo).max(EPS_RANGE);
        let scale = (nbins / range).min(MAX_SCALE);
        bins.push(1.0 / scale);
        st.values += x.cols as u64;
        let src = x.row(i);
        let crow = codes.row_mut(i);
        // The old separate deq pass drew no RNG, so fusing it here keeps
        // both the draw order and the deq values bitwise identical
        // (deq computes from the pre-centering raw code q).
        for ((c, d), &v) in crow.iter_mut().zip(deq.row_mut(i).iter_mut()).zip(src) {
            let t = scale * (v - lo);
            let raw = sr::sr(t, rng);
            let q = raw.clamp(0.0, nbins);
            st.clipped += u64::from(raw != q);
            st.zero_codes += u64::from(q == 0.0);
            if sample_variance {
                let p = f64::from(t) - f64::from(t.floor());
                pvar += p * (1.0 - p) / f64::from(scale).powi(2);
            }
            let (s, moved) = codes::center_code(q, center);
            *c = s;
            saturated += u64::from(moved);
            *d = q / scale + lo;
        }
    }
    codes.saturated = saturated;
    if sample_variance {
        st.sr_variance = Some(pvar);
    }
    (
        Quantized {
            codes,
            deq,
            row_bin_size: bins,
        },
        st,
    )
}

/// Fused quantize-dequantize into a caller-owned buffer: the per-row
/// (min, max) is reduced inline (no `row_minmax` vector), codes and
/// dequantized values come out of one loop (the separate deq pass draws
/// no RNG, so fusing it preserves the draw order), and nothing is
/// allocated once `out` has warmed up to shape. Bitwise identical to
/// `quantize(x, nbins, rng).deq`.
pub fn apply_into(x: &Mat, nbins: f32, rng: &mut Pcg32, out: &mut Mat) {
    let tel = crate::obs::quant::psq();
    let sample_variance = tel.should_sample();
    let mut st = QuantStats::default();
    out.resize(x.rows, x.cols);
    let mut pvar = 0.0f64;
    for i in 0..x.rows {
        let (lo, hi) = super::tensor::minmax_slice(x.row(i));
        if (hi - lo).is_nan() {
            st.poisoned_rows += 1;
            for d in out.row_mut(i) {
                *d = f32::NAN;
            }
            continue;
        }
        let range = (hi - lo).max(EPS_RANGE);
        let scale = (nbins / range).min(MAX_SCALE);
        st.values += x.cols as u64;
        for (d, &v) in out.row_mut(i).iter_mut().zip(x.row(i)) {
            let t = scale * (v - lo);
            let raw = sr::sr(t, rng);
            let q = raw.clamp(0.0, nbins);
            st.clipped += u64::from(raw != q);
            st.zero_codes += u64::from(q == 0.0);
            if sample_variance {
                let p = f64::from(t) - f64::from(t.floor());
                pvar += p * (1.0 - p) / f64::from(scale).powi(2);
            }
            *d = q / scale + lo;
        }
    }
    if sample_variance {
        st.sr_variance = Some(pvar);
    }
    tel.record(&st);
}

/// Integer-code hot path: same math, RNG draw order and telemetry
/// cadence as [`apply_into`], emitting centered i8 codes plus per-row
/// [`CodeScales`]. Unlike PTQ this *also* fills `deq` (bitwise identical
/// to `apply_into`): the per-sample scales sit on the contraction axis
/// of the backward weight-gradient GEMMs, so those two products cannot
/// fold the scales into an integer epilogue and stay on the f32 path
/// (DESIGN.md §5.1) — only the hidden-gradient GEMM consumes the codes.
pub fn quantize_codes_into(
    x: &Mat,
    nbins: f32,
    rng: &mut Pcg32,
    codes: &mut CodeMat,
    scales: &mut CodeScales,
    deq: &mut Mat,
) {
    let tel = crate::obs::quant::psq();
    let sample_variance = tel.should_sample();
    let mut st = QuantStats::default();
    let center = codes::center_for(nbins);
    codes.resize(x.rows, x.cols, center);
    scales.resize_rows(x.rows);
    deq.resize(x.rows, x.cols);
    let mut pvar = 0.0f64;
    let mut saturated = 0u64;
    for i in 0..x.rows {
        let (lo, hi) = super::tensor::minmax_slice(x.row(i));
        if (hi - lo).is_nan() {
            st.poisoned_rows += 1;
            codes.poison_row(i);
            scales.inv[i] = f32::NAN;
            scales.zero[i] = f32::NAN;
            for d in deq.row_mut(i) {
                *d = f32::NAN;
            }
            continue;
        }
        let range = (hi - lo).max(EPS_RANGE);
        let scale = (nbins / range).min(MAX_SCALE);
        st.values += x.cols as u64;
        scales.inv[i] = 1.0 / scale;
        scales.zero[i] = lo + center as f32 / scale;
        let src = x.row(i);
        let crow = codes.row_mut(i);
        for ((c, d), &v) in crow.iter_mut().zip(deq.row_mut(i).iter_mut()).zip(src) {
            let t = scale * (v - lo);
            let raw = sr::sr(t, rng);
            let q = raw.clamp(0.0, nbins);
            st.clipped += u64::from(raw != q);
            st.zero_codes += u64::from(q == 0.0);
            if sample_variance {
                let p = f64::from(t) - f64::from(t.floor());
                pvar += p * (1.0 - p) / f64::from(scale).powi(2);
            }
            let (s, moved) = codes::center_code(q, center);
            *c = s;
            saturated += u64::from(moved);
            *d = q / scale + lo;
        }
    }
    codes.saturated = saturated;
    if sample_variance {
        st.sr_variance = Some(pvar);
    }
    tel.record(&st);
}

/// §4.1 bound: D/(4B^2) * sum_i R(x_i)^2.
pub fn variance_bound(x: &Mat, nbins: f32) -> f64 {
    let sum_r2: f64 = x
        .row_minmax()
        .iter()
        .map(|&(lo, hi)| f64::from(hi - lo).powi(2))
        .sum();
    x.cols as f64 / (4.0 * f64::from(nbins).powi(2)) * sum_r2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::ptq;

    fn skewed(n: usize, d: usize, seed: u64) -> Mat {
        let mut rng = Pcg32::new(seed, 0);
        let mut m = Mat::zeros(n, d);
        for i in 0..n {
            let s = if i == 0 { 5.0 } else { 0.02 };
            for v in m.row_mut(i) {
                *v = rng.normal() * s;
            }
        }
        m
    }

    #[test]
    fn bound_no_larger_than_ptq_bound() {
        let x = skewed(16, 24, 2);
        let b = 15.0;
        assert!(psq_bound_le_ptq(&x, b));
        // and on iid data too (bounds equal only if all rows share range)
        let mut rng = Pcg32::new(3, 0);
        let mut y = Mat::zeros(8, 8);
        for v in &mut y.data {
            *v = rng.normal();
        }
        assert!(psq_bound_le_ptq(&y, b));
    }

    fn psq_bound_le_ptq(x: &Mat, b: f32) -> bool {
        variance_bound(x, b) <= ptq::variance_bound(x, b) + 1e-9
    }

    #[test]
    fn per_row_reconstruction_error_bounded_by_row_bin() {
        let x = skewed(8, 32, 5);
        let mut rng = Pcg32::new(6, 6);
        let q = quantize(&x, 15.0, &mut rng);
        for i in 0..x.rows {
            let bin = q.row_bin_size[i];
            for (d, v) in q.deq.row(i).iter().zip(x.row(i)) {
                assert!((d - v).abs() <= bin * 1.001);
            }
        }
        // outlier row got a much larger bin than the quiet rows
        assert!(q.row_bin_size[0] > 50.0 * q.row_bin_size[3]);
    }

    #[test]
    fn empirical_variance_below_bound_and_below_ptq() {
        let x = skewed(12, 16, 9);
        let b = 15.0;
        let reps = 400;
        let mut rng = Pcg32::new(10, 0);
        let (mut vp, mut vs) = (0.0f64, 0.0f64);
        for _ in 0..reps {
            vp += ptq::quantize(&x, b, &mut rng).deq.sq_err(&x);
            vs += quantize(&x, b, &mut rng).deq.sq_err(&x);
        }
        vp /= f64::from(reps);
        vs /= f64::from(reps);
        assert!(vs <= variance_bound(&x, b));
        assert!(vs < vp, "psq {vs} !< ptq {vp}");
    }

    #[test]
    fn nan_row_poisoned_clean_rows_untouched() {
        let mut x = skewed(4, 8, 3);
        x.row_mut(1)[5] = f32::NAN;
        let mut rng = Pcg32::new(7, 7);
        let q = quantize(&x, 15.0, &mut rng);
        assert!(q.deq.row(1).iter().all(|v| v.is_nan()));
        assert!(q.row_bin_size[1].is_nan());
        for i in [0usize, 2, 3] {
            assert!(q.deq.row(i).iter().all(|v| v.is_finite()), "row {i}");
            let bin = q.row_bin_size[i];
            for (d, v) in q.deq.row(i).iter().zip(x.row(i)) {
                assert!((d - v).abs() <= bin * 1.001);
            }
        }
    }

    #[test]
    fn stats_count_zero_codes_and_poisoned_rows_exactly() {
        // Row 0 = [0,0,0,1]: codes 0,0,0,15 deterministically (sr(0)=0,
        // sr(15)=15 for any u<1) => 3 zero codes. Row 1 carries NaN.
        let x = Mat::from_vec(2, 4, vec![0.0, 0.0, 0.0, 1.0, 1.0, f32::NAN, 2.0, 3.0]);
        let mut rng = Pcg32::new(13, 5);
        let (q, st) = quantize_stats(&x, 15.0, &mut rng, true);
        assert_eq!(st.values, 4, "only the clean row counts");
        assert_eq!(st.zero_codes, 3);
        assert_eq!(st.clipped, 0);
        assert_eq!(st.poisoned_rows, 1);
        assert_eq!(st.sr_variance, Some(0.0));
        assert_eq!(&q.codes.raw_f32()[..4], &[0.0, 0.0, 0.0, 15.0]);
        assert!(q.codes.is_poisoned_row(1));
    }

    /// The codes entry point matches the stats path codewise, matches
    /// `apply_into` bitwise on deq, and keeps the RNG stream in step.
    #[test]
    fn codes_path_matches_stats_and_fused_paths() {
        let mut x = skewed(6, 10, 4);
        x.row_mut(2)[3] = f32::NAN; // one poisoned row in the middle
        let mut ra = Pcg32::new(19, 8);
        let mut rb = Pcg32::new(19, 8);
        let mut rc = Pcg32::new(19, 8);
        let (q, _) = quantize_stats(&x, 15.0, &mut ra, false);
        let mut codes = CodeMat::default();
        let mut scales = CodeScales::default();
        let mut deq = Mat::zeros(0, 0);
        quantize_codes_into(&x, 15.0, &mut rb, &mut codes, &mut scales, &mut deq);
        let mut fused = Mat::zeros(0, 0);
        apply_into(&x, 15.0, &mut rc, &mut fused);
        assert_eq!(ra.uniform(), rb.uniform(), "rng streams diverged");
        assert_eq!(q.codes.data, codes.data);
        assert_eq!(q.codes.poisoned, codes.poisoned);
        assert_eq!(deq, fused, "codes-path deq != apply_into deq");
        assert!(scales.per_row);
        assert!(codes.is_poisoned_row(2));
        assert!(scales.inv[2].is_nan() && scales.zero[2].is_nan());
        for i in [0usize, 1, 3, 4, 5] {
            assert!((scales.inv[i] - q.row_bin_size[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn stats_path_consumes_identical_rng_draws() {
        let x = skewed(6, 10, 4);
        let mut ra = Pcg32::new(17, 8);
        let mut rb = Pcg32::new(17, 8);
        let qa = quantize_stats(&x, 15.0, &mut ra, true).0;
        let qb = quantize_stats(&x, 15.0, &mut rb, false).0;
        assert_eq!(qa.deq, qb.deq);
        assert_eq!(ra.uniform(), rb.uniform(), "rng streams diverged");
    }

    #[test]
    fn zero_rows_reproduced_exactly() {
        let mut x = skewed(4, 8, 1);
        for v in x.row_mut(2) {
            *v = 0.0;
        }
        let mut rng = Pcg32::new(2, 2);
        let q = quantize(&x, 15.0, &mut rng);
        for &d in q.deq.row(2) {
            assert_eq!(d, 0.0);
        }
    }
}
