//! Block Householder quantizer (paper §4.2, Appendix D.4–D.5).
//!
//! Rows are partitioned into groups, each with one "large" leader row.
//! Within a group of size m the scale matrix is S = Q diag(s1, s2..s2)
//! where Q = I - 2 n n^T / |n|^2 is the Householder reflection with
//! n = 1/sqrt(m) - e_leader: Q spreads the leader's signal evenly over
//! the group before rounding, turning the leader's O(lambda_1^2) rounding
//! noise into O(lambda_1^2 / m). Optimal per-group scales (App. D.4):
//!
//!   s1 ∝ lambda1^{-1/3} m^{1/6},  s2 ∝ lambda2^{-1/3} m^{1/6},
//!   normalized so lambda1 s1 m^{-1/2} + lambda2 s2 m^{1/2} = B.
//!
//! Group construction is the Appendix-D.5 heuristic. This implementation
//! applies the reflections groupwise in O(N*D) — the "two sparse-dense
//! matmuls, 2ND FLOPs" the paper's §4.3 overhead study measures — rather
//! than materializing a dense N x N matrix like the JAX trace does.

use super::codes;
use super::{CodeMat, Mat, QuantStats, Quantized, EPS_RANGE, MAX_SCALE};
use crate::quant::sr;
use crate::util::rng::Pcg32;

/// One row-group: `rows` are indices into the *sorted* row order; the
/// leader is always `rows[0]` (the largest-magnitude member).
#[derive(Clone, Debug, PartialEq)]
pub struct Group {
    pub rows: Vec<usize>,
    pub s1: f32,
    pub s2: f32,
}

/// The full transform plan: sorted order + groups.
#[derive(Clone, Debug)]
pub struct Plan {
    /// order[k] = original index of the k-th largest-magnitude row.
    pub order: Vec<usize>,
    pub groups: Vec<Group>,
    pub n_groups: usize,
}

/// Which variance proxy drives the Appendix-D.5 group-count sweep.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Proxy {
    /// The proxy as printed in Appendix D.5: sum_i M_i^2 / m_i with
    /// m_i = 1 + (N-G) * M_i / sum_{j<G} M_j. Blind to a large row that
    /// falls *inside* a group (its lam2 never enters the score).
    Paper,
    /// Full D.4 per-group bound with lam2 ~ 2 M_G (largest non-leader):
    /// sum_i (M_i^{2/3} m_i^{-1/3} + lam2^{2/3} m_i^{2/3})^3. Reduces to
    /// `Paper` as lam2 -> 0. Default; ablated by `exp ablate-bhq-proxy`.
    Extended,
}

/// Score one candidate group count with the selected variance proxy.
fn proxy_score(sorted_mags: &[f32], g: usize, proxy: Proxy) -> f64 {
    let n = sorted_mags.len();
    let tot: f64 = sorted_mags[..g].iter().map(|&m| f64::from(m)).sum();
    let tot = tot.max(f64::from(EPS_RANGE));
    let lam2 = 2.0 * f64::from(sorted_mags.get(g).copied().unwrap_or(0.0));
    sorted_mags[..g]
        .iter()
        .map(|&m| {
            let m = f64::from(m);
            let size = 1.0 + (n - g) as f64 * m / tot;
            match proxy {
                Proxy::Paper => m * m / size,
                Proxy::Extended => {
                    let a = m.max(f64::from(EPS_RANGE)).powf(2.0 / 3.0) * size.powf(-1.0 / 3.0);
                    let b = lam2.powf(2.0 / 3.0) * size.powf(2.0 / 3.0);
                    (a + b).powi(3)
                }
            }
        })
        .sum()
}

/// Appendix-D.5 step 2: sweep candidate group counts G in powers of two,
/// score each with the selected variance proxy, pick the argmin.
/// Candidate order (ascending powers of two, then N) and the strict `<`
/// argmin are load-bearing: ties keep the earlier candidate, and the
/// fused path relies on replaying the identical choice.
pub fn select_group_count_with(sorted_mags: &[f32], proxy: Proxy) -> usize {
    let n = sorted_mags.len();
    if n == 0 {
        return 0; // empty matrix: no rows, no groups
    }
    // powers of two up to N/2, plus G = N (all-singleton = PSQ fallback:
    // Q = I, s1 = B/R — essential on homogeneous gradients, where any
    // grouping smears equal rows together and inflates variance ~ m^2).
    let mut best_g = 1;
    let mut best = f64::INFINITY;
    let mut saw_n = false;
    let mut g = 1;
    while g <= (n / 2).max(1) {
        saw_n |= g == n;
        let score = proxy_score(sorted_mags, g, proxy);
        if score < best {
            best = score;
            best_g = g;
        }
        g *= 2;
    }
    if !saw_n && proxy_score(sorted_mags, n, proxy) < best {
        best_g = n;
    }
    best_g
}

/// Default (extended-proxy) group-count selection.
pub fn select_group_count(sorted_mags: &[f32]) -> usize {
    select_group_count_with(sorted_mags, Proxy::Extended)
}

/// Build the groups: leaders are the top-G sorted rows; the remaining
/// N-G rows are dealt to groups proportionally to leader magnitude
/// (cumulative-boundary assignment — identical to the JAX trace).
pub fn build_plan(x: &Mat) -> Plan {
    build_plan_with(x, Proxy::Extended)
}

pub fn build_plan_with(x: &Mat, proxy: Proxy) -> Plan {
    let n = x.rows;
    let mags = x.row_absmax();
    let mut order: Vec<usize> = (0..n).collect();
    // total_cmp: a NaN magnitude (diverged gradient row) must not panic
    // the planner; NaN sorts above every finite value in descending
    // order, and quantize() short-circuits NaN input before reflection.
    order.sort_by(|&a, &b| mags[b].total_cmp(&mags[a]));
    let sorted_mags: Vec<f32> = order.iter().map(|&i| mags[i]).collect();

    let g = select_group_count_with(&sorted_mags, proxy);
    let tot: f64 = sorted_mags[..g].iter().map(|&m| f64::from(m)).sum();
    let tot = tot.max(f64::from(EPS_RANGE));
    // cumulative fractional sizes; non-leader sorted row j (j >= G) goes
    // to the group whose boundary brackets position (j - G + 0.5).
    let mut groups: Vec<Group> = (0..g)
        .map(|i| Group {
            rows: vec![i],
            s1: 0.0,
            s2: 0.0,
        })
        .collect();
    let extras: Vec<f64> = sorted_mags[..g]
        .iter()
        .map(|&m| (n - g) as f64 * f64::from(m) / tot)
        .collect();
    let mut bounds = Vec::with_capacity(g);
    let mut acc = 0.0;
    for &e in &extras {
        acc += e;
        bounds.push(acc);
    }
    for j in g..n {
        let pos = (j - g) as f64 + 0.5;
        let gi = bounds
            .iter()
            .position(|&b| pos < b)
            .unwrap_or(g - 1);
        groups[gi].rows.push(j);
    }

    // Per-group optimal scales (App. D.4 with N -> m).
    for grp in &mut groups {
        let m = grp.rows.len() as f64;
        let leader = grp.rows[0];
        let (lo, hi) = {
            let r = x.row(order[leader]);
            let mut lo = f32::INFINITY;
            let mut hi = f32::NEG_INFINITY;
            for &v in r {
                lo = lo.min(v);
                hi = hi.max(v);
            }
            (lo, hi)
        };
        // Floor lam1 relative to the leader's magnitude: a near-constant
        // row (range ~ 0, values large) would otherwise get an enormous
        // s1, and the reflection's f32 cancellation error scales with
        // s1 * |x|. The floor caps the transform's dynamic range at 1e3,
        // costing nothing (such rows quantize near-exactly anyway).
        let mag_leader = f64::from(sorted_mags[leader]);
        let lam1 = f64::from(hi - lo)
            .max(1e-3 * mag_leader)
            .max(f64::from(EPS_RANGE));
        let lam2 = grp.rows[1..]
            .iter()
            .map(|&k| f64::from(sorted_mags[k]))
            .fold(0.0f64, f64::max)
            * 2.0;
        let lam2 = lam2.max(f64::from(EPS_RANGE));
        // normalized with B folded in by the caller (scales below are per
        // unit B; quantize() multiplies by nbins).
        let denom = lam1.powf(2.0 / 3.0) * m.powf(-1.0 / 3.0)
            + lam2.powf(2.0 / 3.0) * m.powf(2.0 / 3.0);
        let denom = denom.max(f64::from(EPS_RANGE));
        grp.s1 = ((lam1.powf(-1.0 / 3.0) * m.powf(1.0 / 6.0)) / denom)
            .min(f64::from(MAX_SCALE)) as f32;
        grp.s2 = ((lam2.powf(-1.0 / 3.0) * m.powf(1.0 / 6.0)) / denom)
            .min(f64::from(MAX_SCALE)) as f32;
    }

    Plan {
        order,
        n_groups: g,
        groups,
    }
}

/// Apply the blockwise Householder reflection in place on *sorted* rows:
/// for each group, y_i <- y_i - 2 n_i (n . y_col) / |n|^2 per column,
/// where n_i = 1/sqrt(m) - [i == leader]. O(m * D) per group.
fn reflect(rows_sorted: &mut [Vec<f32>], grp: &Group) {
    let m = grp.rows.len();
    if m == 1 {
        return; // n = 0 -> identity
    }
    let d = rows_sorted[grp.rows[0]].len();
    let inv_sqrt_m = 1.0 / (m as f32).sqrt();
    // n entries: leader -> inv_sqrt_m - 1, member -> inv_sqrt_m
    let n_leader = inv_sqrt_m - 1.0;
    let nsq = n_leader * n_leader + (m - 1) as f32 * inv_sqrt_m * inv_sqrt_m;
    let coef = 2.0 / nsq;
    let mut t = vec![0.0f32; d];
    for (gi, &r) in grp.rows.iter().enumerate() {
        let ni = if gi == 0 { n_leader } else { inv_sqrt_m };
        for (tj, &v) in t.iter_mut().zip(&rows_sorted[r]) {
            *tj += ni * v;
        }
    }
    for (gi, &r) in grp.rows.iter().enumerate() {
        let ni = if gi == 0 { n_leader } else { inv_sqrt_m };
        let f = coef * ni;
        for (v, &tj) in rows_sorted[r].iter_mut().zip(&t) {
            *v -= f * tj;
        }
    }
}

pub fn quantize(x: &Mat, nbins: f32, rng: &mut Pcg32) -> Quantized {
    quantize_with(x, nbins, rng, Proxy::Extended)
}

/// BHQ with an explicit group-count proxy (the `ablate-bhq-proxy` knob).
pub fn quantize_with(x: &Mat, nbins: f32, rng: &mut Pcg32, proxy: Proxy) -> Quantized {
    let tel = crate::obs::quant::bhq();
    let (q, st) = quantize_stats(x, nbins, rng, proxy, tel.should_sample());
    tel.record(&st);
    q
}

/// [`quantize_with`] plus per-call telemetry; identical RNG draw order.
/// The exact SR variance is measured in *transformed* space,
/// sum p(1-p)/srow_k^2 — the Thm-1 noise the reflection is designed to
/// shrink — and computed only when `sample_variance`.
pub fn quantize_stats(
    x: &Mat,
    nbins: f32,
    rng: &mut Pcg32,
    proxy: Proxy,
    sample_variance: bool,
) -> (Quantized, QuantStats) {
    let mut st = QuantStats::default();
    // NaN anywhere poisons the whole output: the Householder reflection
    // mixes rows within a group, and `sr(NaN).max(0.0)` would otherwise
    // silently turn a diverged row into finite garbage for the group.
    if x.data.iter().any(|v| v.is_nan()) {
        st.poisoned_rows = x.rows as u64;
        return (super::poisoned(x.rows, x.cols, nbins), st);
    }
    let plan = build_plan_with(x, proxy);
    let n = x.rows;
    let d = x.cols;

    // Gather sorted rows, scale by per-row s (s1 leader / s2 member) * B.
    let mut srow = vec![0.0f32; n];
    for grp in &plan.groups {
        for (gi, &k) in grp.rows.iter().enumerate() {
            srow[k] = nbins * if gi == 0 { grp.s1 } else { grp.s2 };
        }
    }
    let mut ys: Vec<Vec<f32>> = (0..n)
        .map(|k| {
            let src = x.row(plan.order[k]);
            src.iter().map(|&v| v * srow[k]).collect()
        })
        .collect();

    // Rotate: Y = Q diag(s) X.
    for grp in &plan.groups {
        reflect(&mut ys, grp);
    }

    // Per-row zero point in transformed space + SR. The raw code q is
    // written back into `ys` (the reconstruction input): BHQ codes are
    // one-sided above, so the i8 `CodeMat` store may saturate (counted),
    // and the dequantization must use the unsaturated value to keep the
    // estimator unbiased and bitwise identical to the pre-CodeMat path.
    let mut codes = CodeMat::zeros(n, d, codes::center_for(nbins));
    let center = codes.center;
    let mut saturated = 0u64;
    let mut zs = vec![0.0f32; n];
    let mut pvar = 0.0f64;
    for k in 0..n {
        let lo = ys[k].iter().fold(f32::INFINITY, |a, &v| a.min(v));
        zs[k] = if lo.is_finite() { lo } else { 0.0 };
        let inv_s2 = if sample_variance {
            1.0 / f64::from(srow[k]).powi(2)
        } else {
            0.0
        };
        let crow = codes.row_mut(k);
        for (c, v) in crow.iter_mut().zip(ys[k].iter_mut()) {
            let t = *v - zs[k];
            let raw = sr::sr(t, rng);
            let q = raw.max(0.0);
            st.clipped += u64::from(raw != q);
            st.zero_codes += u64::from(q == 0.0);
            if sample_variance {
                let p = f64::from(t) - f64::from(t.floor());
                pvar += p * (1.0 - p) * inv_s2;
            }
            let (s, moved) = codes::center_code(q, center);
            *c = s;
            saturated += u64::from(moved);
            *v = q;
        }
    }
    codes.saturated = saturated;
    st.values = (n * d) as u64;
    if sample_variance {
        st.sr_variance = Some(pvar);
    }

    // Reconstruct: X^ = diag(1/s) Q (q + z)   (Q^2 = I), from the raw
    // codes now held in `ys`.
    let mut rec: Vec<Vec<f32>> = (0..n)
        .map(|k| ys[k].iter().map(|&q| q + zs[k]).collect())
        .collect();
    for grp in &plan.groups {
        reflect(&mut rec, grp);
    }
    let mut deq = Mat::zeros(n, d);
    let mut row_bin = vec![0.0f32; n];
    for k in 0..n {
        let orig = plan.order[k];
        let inv_s = 1.0 / srow[k];
        row_bin[orig] = inv_s;
        let drow = deq.row_mut(orig);
        for (o, &v) in drow.iter_mut().zip(&rec[k]) {
            *o = v * inv_s;
        }
    }
    (
        Quantized {
            codes,
            deq,
            row_bin_size: row_bin,
        },
        st,
    )
}

/// One group in the fused plan: leader is the sorted index equal to the
/// group's position, extras are a contiguous `[start, end)` range of
/// sorted indices (the cumulative-boundary assignment deals ascending
/// positions to ascending groups, so membership is always contiguous).
struct GroupSpan {
    extras: (usize, usize),
    s1: f32,
    s2: f32,
}

/// Reusable buffers for [`apply_into`]: the index sort, the plan, and
/// the transformed-row matrix all live here across calls, so a warm
/// scratch makes the fused BHQ path allocation-free.
#[derive(Default)]
pub struct Scratch {
    mags: Vec<f32>,
    order: Vec<usize>,
    sorted_mags: Vec<f32>,
    bounds: Vec<f64>,
    spans: Vec<GroupSpan>,
    srow: Vec<f32>,
    ys: Mat,
    t: Vec<f32>,
}

/// [`reflect`] on the flat sorted-row matrix with a caller-owned
/// accumulator — same per-column addition order (leader first, then
/// members ascending), so results are bitwise identical.
fn reflect_span(ys: &mut Mat, leader: usize, extras: (usize, usize), t: &mut [f32]) {
    let m = 1 + extras.1 - extras.0;
    if m == 1 {
        return; // n = 0 -> identity
    }
    let inv_sqrt_m = 1.0 / (m as f32).sqrt();
    let n_leader = inv_sqrt_m - 1.0;
    let nsq = n_leader * n_leader + (m - 1) as f32 * inv_sqrt_m * inv_sqrt_m;
    let coef = 2.0 / nsq;
    t.fill(0.0);
    for (tj, &v) in t.iter_mut().zip(ys.row(leader)) {
        *tj += n_leader * v;
    }
    for r in extras.0..extras.1 {
        for (tj, &v) in t.iter_mut().zip(ys.row(r)) {
            *tj += inv_sqrt_m * v;
        }
    }
    let f = coef * n_leader;
    for (v, &tj) in ys.row_mut(leader).iter_mut().zip(t.iter()) {
        *v -= f * tj;
    }
    let f = coef * inv_sqrt_m;
    for r in extras.0..extras.1 {
        for (v, &tj) in ys.row_mut(r).iter_mut().zip(t.iter()) {
            *v -= f * tj;
        }
    }
}

/// Fused quantize-dequantize into a caller-owned buffer, bitwise
/// identical to `quantize(x, nbins, rng).deq` (extended proxy): the plan
/// arithmetic, reflection order, RNG draw order, and telemetry cadence
/// all replay exactly. Differences are purely structural — the index
/// sort and plan reuse `scratch`, groups are `(leader, extras-range)`
/// spans instead of per-group index vectors, the transformed rows live
/// in one flat matrix instead of `Vec<Vec<f32>>`, and the codes matrix
/// is never materialized (codes + zero point are written back in place).
pub fn apply_into(x: &Mat, nbins: f32, rng: &mut Pcg32, scratch: &mut Scratch, out: &mut Mat) {
    let tel = crate::obs::quant::bhq();
    let sample_variance = tel.should_sample();
    let mut st = QuantStats::default();
    let (n, d) = (x.rows, x.cols);
    out.resize(n, d);
    if x.data.iter().any(|v| v.is_nan()) {
        st.poisoned_rows = n as u64;
        out.data.fill(f32::NAN);
        tel.record(&st);
        return;
    }
    let Scratch {
        mags,
        order,
        sorted_mags,
        bounds,
        spans,
        srow,
        ys,
        t,
    } = scratch;

    // Plan: descending-magnitude index sort (stable-equivalent via the
    // ascending-index tiebreak; magnitudes are abs-maxes, never -0.0),
    // group-count sweep, contiguous extras assignment, per-group scales —
    // the same arithmetic as `build_plan_with`, minus its allocations.
    mags.clear();
    for i in 0..n {
        let mut m = 0.0f32;
        for &v in x.row(i) {
            m = m.max(v.abs());
        }
        mags.push(m);
    }
    order.clear();
    order.extend(0..n);
    order.sort_unstable_by(|&a, &b| mags[b].total_cmp(&mags[a]).then(a.cmp(&b)));
    sorted_mags.clear();
    sorted_mags.extend(order.iter().map(|&i| mags[i]));
    let g = select_group_count_with(sorted_mags, Proxy::Extended);

    let tot: f64 = sorted_mags[..g].iter().map(|&m| f64::from(m)).sum();
    let tot = tot.max(f64::from(EPS_RANGE));
    bounds.clear();
    let mut acc = 0.0;
    for &m in &sorted_mags[..g] {
        acc += (n - g) as f64 * f64::from(m) / tot;
        bounds.push(acc);
    }
    spans.clear();
    let mut j = g;
    for gi in 0..g {
        let start = j;
        if gi + 1 == g {
            j = n; // last group absorbs the tail (the `unwrap_or(g - 1)`)
        } else {
            while j < n && ((j - g) as f64 + 0.5) < bounds[gi] {
                j += 1;
            }
        }
        spans.push(GroupSpan {
            extras: (start, j),
            s1: 0.0,
            s2: 0.0,
        });
    }
    for (gi, span) in spans.iter_mut().enumerate() {
        let m = (1 + span.extras.1 - span.extras.0) as f64;
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &v in x.row(order[gi]) {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        let mag_leader = f64::from(sorted_mags[gi]);
        let lam1 = f64::from(hi - lo)
            .max(1e-3 * mag_leader)
            .max(f64::from(EPS_RANGE));
        // extras are sorted (descending magnitude), so the largest
        // non-leader is the first one.
        let lam2 = if span.extras.1 > span.extras.0 {
            f64::from(sorted_mags[span.extras.0]) * 2.0
        } else {
            0.0
        };
        let lam2 = lam2.max(f64::from(EPS_RANGE));
        let denom = lam1.powf(2.0 / 3.0) * m.powf(-1.0 / 3.0)
            + lam2.powf(2.0 / 3.0) * m.powf(2.0 / 3.0);
        let denom = denom.max(f64::from(EPS_RANGE));
        span.s1 = ((lam1.powf(-1.0 / 3.0) * m.powf(1.0 / 6.0)) / denom)
            .min(f64::from(MAX_SCALE)) as f32;
        span.s2 = ((lam2.powf(-1.0 / 3.0) * m.powf(1.0 / 6.0)) / denom)
            .min(f64::from(MAX_SCALE)) as f32;
    }

    // Gather + scale sorted rows into the flat transform buffer.
    srow.clear();
    srow.resize(n, 0.0);
    for (gi, span) in spans.iter().enumerate() {
        srow[gi] = nbins * span.s1;
        for s in &mut srow[span.extras.0..span.extras.1] {
            *s = nbins * span.s2;
        }
    }
    ys.resize(n, d);
    for k in 0..n {
        let s = srow[k];
        for (yv, &v) in ys.row_mut(k).iter_mut().zip(x.row(order[k])) {
            *yv = v * s;
        }
    }
    t.resize(d, 0.0);
    for (gi, span) in spans.iter().enumerate() {
        reflect_span(ys, gi, span.extras, t);
    }

    // Per-row zero point + SR, writing `code + z` back in place (the
    // reference path's codes-then-rec split, fused).
    let mut pvar = 0.0f64;
    for k in 0..n {
        let row = ys.row_mut(k);
        let lo = row.iter().fold(f32::INFINITY, |a, &v| a.min(v));
        let z = if lo.is_finite() { lo } else { 0.0 };
        let inv_s2 = if sample_variance {
            1.0 / f64::from(srow[k]).powi(2)
        } else {
            0.0
        };
        for v in row.iter_mut() {
            let tv = *v - z;
            let raw = sr::sr(tv, rng);
            let q = raw.max(0.0);
            st.clipped += u64::from(raw != q);
            st.zero_codes += u64::from(q == 0.0);
            if sample_variance {
                let p = f64::from(tv) - f64::from(tv.floor());
                pvar += p * (1.0 - p) * inv_s2;
            }
            *v = q + z;
        }
    }
    st.values = (n * d) as u64;
    if sample_variance {
        st.sr_variance = Some(pvar);
    }

    // Reflect back (Q^2 = I) and unscale into the original row order.
    for (gi, span) in spans.iter().enumerate() {
        reflect_span(ys, gi, span.extras, t);
    }
    for k in 0..n {
        let inv_s = 1.0 / srow[k];
        for (o, &v) in out.row_mut(order[k]).iter_mut().zip(ys.row(k)) {
            *o = v * inv_s;
        }
    }
    tel.record(&st);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::psq;

    fn outlier(n: usize, d: usize, seed: u64, big: f32, small: f32) -> Mat {
        let mut rng = Pcg32::new(seed, 0);
        let mut m = Mat::zeros(n, d);
        for i in 0..n {
            let s = if i == 0 { big } else { small };
            for v in m.row_mut(i) {
                *v = rng.normal() * s;
            }
        }
        m
    }

    #[test]
    fn plan_is_a_partition() {
        let x = outlier(32, 16, 3, 10.0, 0.01);
        let plan = build_plan(&x);
        let mut seen = vec![false; 32];
        for g in &plan.groups {
            assert!(!g.rows.is_empty());
            for &r in &g.rows {
                assert!(!seen[r], "row {r} in two groups");
                seen[r] = true;
            }
            // leader is the largest-magnitude member (rows are sorted ids)
            assert!(g.rows[1..].iter().all(|&r| r > g.rows[0]));
        }
        assert!(seen.into_iter().all(|s| s), "not all rows covered");
        assert_eq!(plan.groups.len(), plan.n_groups);
    }

    #[test]
    fn reflection_is_involution_and_isometry() {
        let x = outlier(16, 8, 5, 3.0, 0.5);
        let plan = build_plan(&x);
        let rows: Vec<Vec<f32>> = (0..16).map(|k| x.row(plan.order[k]).to_vec()).collect();
        let mut y = rows.clone();
        for g in &plan.groups {
            reflect(&mut y, g);
        }
        // isometry: column norms preserved per group
        let norm = |v: &[Vec<f32>]| -> f64 {
            v.iter()
                .flat_map(|r| r.iter())
                .map(|&x| f64::from(x) * f64::from(x))
                .sum()
        };
        assert!((norm(&rows) - norm(&y)).abs() < 1e-3 * norm(&rows).max(1.0));
        for g in &plan.groups {
            reflect(&mut y, g);
        }
        for (a, b) in rows.iter().zip(&y) {
            for (&u, &v) in a.iter().zip(b) {
                assert!((u - v).abs() < 1e-4, "{u} vs {v}");
            }
        }
    }

    #[test]
    fn single_outlier_selects_few_groups_and_beats_psq() {
        // The §4.2 extreme case: lambda2/lambda1 ~ 0. BHQ variance should
        // be ~O(lambda1^2/N) vs PSQ's O(lambda1^2).
        let x = outlier(32, 32, 7, 10.0, 0.001);
        let b = 15.0;
        let reps = 300;
        let mut rng = Pcg32::new(11, 0);
        let (mut vb, mut vs) = (0.0f64, 0.0f64);
        for _ in 0..reps {
            vb += quantize(&x, b, &mut rng).deq.sq_err(&x);
            vs += psq::quantize(&x, b, &mut rng).deq.sq_err(&x);
        }
        vb /= f64::from(reps);
        vs /= f64::from(reps);
        assert!(vb < vs / 3.0, "bhq {vb} !<< psq {vs}");
    }

    #[test]
    fn unbiased_on_outlier_structure() {
        let x = outlier(8, 16, 9, 5.0, 0.01);
        let reps = 3000;
        let mut rng = Pcg32::new(13, 0);
        let mut mean = vec![0.0f64; x.len()];
        let mut sq = vec![0.0f64; x.len()];
        for _ in 0..reps {
            let q = quantize(&x, 15.0, &mut rng);
            for ((m, s), &v) in mean.iter_mut().zip(sq.iter_mut()).zip(&q.deq.data) {
                *m += f64::from(v);
                *s += f64::from(v) * f64::from(v);
            }
        }
        let nrep = f64::from(reps);
        for i in 0..x.len() {
            let m = mean[i] / nrep;
            let var = (sq[i] / nrep - m * m).max(0.0);
            let se = (var / nrep).sqrt();
            let diff = (m - f64::from(x.data[i])).abs();
            // floor covers near-zero-variance elements reproduced (up to
            // the f32 scale->reflect->reflect->unscale round-trip error,
            // ~1e-4 relative) deterministically: the tiny deterministic
            // residual is transform round-off, not estimator bias.
            if diff < 1e-3 * f64::from(x.data[i].abs()) + 1e-6 {
                continue;
            }
            let z = diff / (se + 1e-12);
            assert!(z < 6.0, "elem {i}: z={z} mean {m} x {}", x.data[i]);
        }
    }

    /// Regression: the seed planner sorted with
    /// `partial_cmp(..).unwrap()`, which panics the moment one gradient
    /// row contains NaN. The plan must build (total_cmp) and the
    /// quantizer must return a poisoned output instead of aborting.
    #[test]
    fn nan_row_does_not_panic_and_poisons_output() {
        let mut x = outlier(8, 8, 17, 4.0, 0.1);
        x.row_mut(3)[2] = f32::NAN;
        let plan = build_plan(&x); // seed code: panic here
        assert_eq!(plan.order.len(), 8);
        let mut rng = Pcg32::new(9, 9);
        let q = quantize(&x, 15.0, &mut rng);
        assert!(q.deq.data.iter().all(|v| v.is_nan()));
        assert!(q.codes.poisoned.iter().all(|&p| p));
        assert!(q.codes.raw_f32().iter().all(|v| v.is_nan()));
    }

    /// Regression: the group-count sweep indexed `sorted_mags[..1]` on an
    /// empty matrix.
    #[test]
    fn empty_and_degenerate_shapes_do_not_panic() {
        let mut rng = Pcg32::new(1, 1);
        for (r, c) in [(0usize, 0usize), (0, 5), (5, 0)] {
            let x = Mat::zeros(r, c);
            let q = quantize(&x, 15.0, &mut rng);
            assert_eq!(q.deq.rows, r);
            assert_eq!(q.deq.cols, c);
            assert!(q.deq.data.iter().all(|v| *v == 0.0));
        }
        assert_eq!(select_group_count(&[]), 0);
    }

    #[test]
    fn stats_cover_every_value_and_count_row_minima_as_zero_codes() {
        let x = outlier(8, 16, 23, 4.0, 0.05);
        let mut rng = Pcg32::new(5, 5);
        let (q, st) = quantize_stats(&x, 15.0, &mut rng, Proxy::Extended, true);
        assert_eq!(st.values, 8 * 16);
        // each transformed row's minimum codes to sr(0) = 0 exactly
        assert!(st.zero_codes >= 8, "zero codes {}", st.zero_codes);
        assert_eq!(st.poisoned_rows, 0);
        let v = st.sr_variance.expect("sampled");
        assert!(v.is_finite() && v >= 0.0, "sr variance {v}");
        assert!(q.deq.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn stats_path_consumes_identical_rng_draws() {
        let x = outlier(8, 8, 29, 3.0, 0.1);
        let mut ra = Pcg32::new(19, 6);
        let mut rb = Pcg32::new(19, 6);
        let qa = quantize_stats(&x, 15.0, &mut ra, Proxy::Extended, true).0;
        let qb = quantize_stats(&x, 15.0, &mut rb, Proxy::Extended, false).0;
        assert_eq!(qa.deq, qb.deq);
        assert_eq!(ra.uniform(), rb.uniform(), "rng streams diverged");
    }

    #[test]
    fn uniform_rows_pick_one_group_per_leader_ok() {
        // iid rows: heuristic may pick any G; quantizer must stay valid.
        let mut rng = Pcg32::new(21, 0);
        let mut x = Mat::zeros(16, 16);
        for v in &mut x.data {
            *v = rng.normal();
        }
        let q = quantize(&x, 255.0, &mut rng);
        // high bitwidth -> reconstruction should be tight
        let rel = q.deq.sq_err(&x) / x.frob_sq();
        assert!(rel < 1e-3, "rel err {rel}");
    }
}
