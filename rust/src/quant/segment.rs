//! Segment/chunk quantization over flat slices (S12): the ring
//! all-reduce payload path.
//!
//! A worker's outgoing ring segment is a flat f32 run with no sample
//! structure, so it is reshaped into `chunk`-wide rows before entering
//! the paper stack — PSQ then yields per-chunk scales and BHQ gets a
//! block structure to mix — and flattened back after dequantization. A
//! ragged tail shorter than `chunk` is quantized as its own short row,
//! which only changes that tail's scale statistics, never unbiasedness
//! (Thm 1 holds per matrix).

use super::{bfp, bhq, fp8, nbins, psq, ptq, GradQuantizer, Mat, QuantStats};
use crate::util::rng::Pcg32;

/// Quantize-dequantize a flat slice at `bits`, reshaped into rows of
/// `chunk` elements. Telemetry is recorded into the quantizer's
/// `obs::quant` sink exactly like the whole-matrix path; the RNG draw
/// order depends only on the input and `chunk`, never on the sampling
/// cadence, so determinism-given-seed is unaffected.
pub fn quantize_slice(
    q: GradQuantizer,
    xs: &[f32],
    bits: f32,
    chunk: usize,
    rng: &mut Pcg32,
) -> (Vec<f32>, QuantStats) {
    if bits <= 0.0 || xs.is_empty() {
        return (xs.to_vec(), QuantStats::default());
    }
    let chunk = chunk.max(1);
    let nb = nbins(bits);
    let tel = crate::obs::quant::by_name(q.name());
    let sample = tel.is_some_and(|t| t.should_sample());
    let body_rows = xs.len() / chunk;
    let tail = xs.len() - body_rows * chunk;
    let mut out = Vec::with_capacity(xs.len());
    let mut st = QuantStats::default();
    if body_rows > 0 {
        let m = Mat::from_vec(body_rows, chunk, xs[..body_rows * chunk].to_vec());
        let (deq, s) = apply_stats(q, &m, nb, rng, sample);
        out.extend_from_slice(&deq.data);
        st.merge(&s);
    }
    if tail > 0 {
        let m = Mat::from_vec(1, tail, xs[body_rows * chunk..].to_vec());
        let (deq, s) = apply_stats(q, &m, nb, rng, sample);
        out.extend_from_slice(&deq.data);
        st.merge(&s);
    }
    if let Some(t) = tel {
        t.record(&st);
    }
    (out, st)
}

/// Stats-aware quantize-dequantize dispatch over one reshaped block.
/// The Table-2 formats (fp8/bfp) have no stats path; they report only
/// the value count.
fn apply_stats(
    q: GradQuantizer,
    x: &Mat,
    nb: f32,
    rng: &mut Pcg32,
    sample: bool,
) -> (Mat, QuantStats) {
    match q {
        GradQuantizer::Ptq => {
            let (o, st) = ptq::quantize_stats(x, nb, rng, sample);
            (o.deq, st)
        }
        GradQuantizer::Psq => {
            let (o, st) = psq::quantize_stats(x, nb, rng, sample);
            (o.deq, st)
        }
        GradQuantizer::Bhq => {
            let (o, st) = bhq::quantize_stats(x, nb, rng, bhq::Proxy::Extended, sample);
            (o.deq, st)
        }
        GradQuantizer::Fp8 => (
            fp8::quantize(x, rng),
            QuantStats {
                values: x.len() as u64,
                ..QuantStats::default()
            },
        ),
        GradQuantizer::Bfp => (
            bfp::quantize(x, nb, 64, rng),
            QuantStats {
                values: x.len() as u64,
                ..QuantStats::default()
            },
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn noise(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg32::new(seed, 0);
        (0..n).map(|_| rng.normal()).collect()
    }

    #[test]
    fn zero_bits_is_identity() {
        let xs = noise(100, 1);
        let mut rng = Pcg32::new(2, 0);
        let (out, st) = quantize_slice(GradQuantizer::Psq, &xs, 0.0, 16, &mut rng);
        assert_eq!(out, xs);
        assert_eq!(st, QuantStats::default());
    }

    /// When `chunk` divides the slice, the segment path is bitwise the
    /// whole-matrix quantizer on the reshaped input (same RNG draws).
    #[test]
    fn divisible_slice_matches_whole_matrix_path() {
        let xs = noise(96, 3);
        for q in GradQuantizer::PAPER {
            let (out, _) = quantize_slice(q, &xs, 4.0, 32, &mut Pcg32::new(7, 9));
            let m = Mat::from_vec(3, 32, xs.clone());
            let whole = q.apply(&m, 4.0, &mut Pcg32::new(7, 9));
            assert_eq!(out, whole.data, "{q:?}");
        }
    }

    /// Ragged tails keep length, stay finite, and stay within one bin of
    /// the input for the affine quantizers.
    #[test]
    fn ragged_tail_quantizes_cleanly() {
        for (n, chunk) in [(37usize, 16usize), (5, 16), (16, 16), (130, 64)] {
            let xs = noise(n, n as u64);
            for q in GradQuantizer::ALL {
                let (out, st) =
                    quantize_slice(q, &xs, 5.0, chunk, &mut Pcg32::new(11, 4));
                assert_eq!(out.len(), n, "{q:?} n={n}");
                assert!(out.iter().all(|v| v.is_finite()), "{q:?} n={n}");
                if GradQuantizer::PAPER.contains(&q) {
                    assert_eq!(st.values, n as u64, "{q:?} n={n}");
                }
            }
        }
    }

    #[test]
    fn same_seed_replays_bitwise() {
        let xs = noise(77, 13);
        let (a, _) = quantize_slice(GradQuantizer::Bhq, &xs, 3.0, 16, &mut Pcg32::new(5, 5));
        let (b, _) = quantize_slice(GradQuantizer::Bhq, &xs, 3.0, 16, &mut Pcg32::new(5, 5));
        assert_eq!(a, b);
        let (c, _) = quantize_slice(GradQuantizer::Bhq, &xs, 3.0, 16, &mut Pcg32::new(6, 5));
        assert_ne!(a, c);
    }

    #[test]
    fn stats_merge_accumulates() {
        let a = QuantStats {
            values: 10,
            clipped: 1,
            zero_codes: 2,
            poisoned_rows: 0,
            sr_variance: Some(0.5),
        };
        let mut b = QuantStats {
            values: 5,
            clipped: 0,
            zero_codes: 1,
            poisoned_rows: 1,
            sr_variance: None,
        };
        b.merge(&a);
        assert_eq!(b.values, 15);
        assert_eq!(b.clipped, 1);
        assert_eq!(b.zero_codes, 3);
        assert_eq!(b.poisoned_rows, 1);
        assert_eq!(b.sr_variance, Some(0.5));
    }
}
