//! Block floating point (HBFP-style, Drumond et al. '18) — Table-2
//! comparison format.
//!
//! Rows are cut into length-`block` chunks; each chunk shares a
//! power-of-two scale chosen so its absmax fits in [-B/2, B/2], and
//! mantissas are stochastically rounded. Power-of-two scales are what
//! make BFP cheap in hardware (shift instead of multiply).

use super::{Mat, EPS_RANGE, MAX_SCALE};
use crate::util::rng::Pcg32;

pub fn quantize(x: &Mat, nbins: f32, block: usize, rng: &mut Pcg32) -> Mat {
    let mut out = Mat::zeros(x.rows, x.cols);
    let half = nbins / 2.0;
    for i in 0..x.rows {
        let src = x.row(i);
        let dst = out.row_mut(i);
        let mut start = 0;
        while start < src.len() {
            let end = (start + block).min(src.len());
            let chunk = &src[start..end];
            let absmax = chunk
                .iter()
                .fold(0.0f32, |a, &v| a.max(v.abs()))
                .max(EPS_RANGE);
            // largest power of two s with absmax * s <= B/2
            let s = 2f32.powf((half / absmax).log2().floor()).min(MAX_SCALE);
            for (o, &v) in dst[start..end].iter_mut().zip(chunk) {
                *o = (v * s + rng.uniform()).floor() / s;
            }
            start = end;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scales_are_powers_of_two() {
        // reconstruct the implied scale from a spike chunk and check it
        // is a power of two: q values are integers / s.
        let x = Mat::from_vec(1, 4, vec![3.0, 0.1, -0.2, 0.05]);
        let mut rng = Pcg32::new(1, 1);
        let q = quantize(&x, 255.0, 4, &mut rng);
        // with absmax 3.0 and B/2=127.5: s = 2^floor(log2(42.5)) = 32
        for (&qv, &_xv) in q.data.iter().zip(&x.data) {
            let scaled = qv * 32.0;
            assert!((scaled - scaled.round()).abs() < 1e-4, "{qv}");
        }
    }

    #[test]
    fn unbiased_and_bounded_error() {
        let mut rng = Pcg32::new(2, 2);
        let mut x = Mat::zeros(2, 128);
        for v in &mut x.data {
            *v = rng.normal();
        }
        let reps = 2000;
        let mut mean = vec![0.0f64; x.len()];
        for _ in 0..reps {
            let q = quantize(&x, 255.0, 64, &mut rng);
            for (m, &v) in mean.iter_mut().zip(&q.data) {
                *m += f64::from(v) / f64::from(reps);
            }
        }
        for (m, &v) in mean.iter().zip(&x.data) {
            assert!((m - f64::from(v)).abs() < 0.01, "{m} vs {v}");
        }
    }

    #[test]
    fn ragged_tail_block_handled() {
        let x = Mat::from_vec(1, 5, vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        let mut rng = Pcg32::new(3, 3);
        let q = quantize(&x, 255.0, 4, &mut rng);
        assert_eq!(q.cols, 5);
        for (&qv, &xv) in q.data.iter().zip(&x.data) {
            assert!((qv - xv).abs() < 0.1);
        }
    }
}
