//! Native Rust implementations of the paper's quantizers (S11).
//!
//! These mirror the L2 JAX quantizers bit-for-bit in structure (same
//! scale/zero/rounding math) and serve three roles:
//!
//!  1. the quantized-gradient **all-reduce** in the data-parallel
//!     coordinator (`coordinator/data_parallel.rs`) — the L3 hot path;
//!  2. the **Fig-4 histogram/bin tooling** (`experiments/fig4.rs`), which
//!     needs the integer codes and bin sizes, not just dequantized values;
//!  3. a **second implementation** cross-checked against the Python one in
//!     integration tests (same input + same noise convention => same
//!     statistics), which is how we validate the AOT path end to end.
//!
//! All gradient quantizers are *unbiased*: deterministic affine transforms
//! composed with stochastic rounding (Theorem 1's only requirement).

pub mod bfp;
pub mod bhq;
pub mod codes;
pub mod fp8;
pub mod psq;
pub mod ptq;
pub mod segment;
pub mod sr;
pub mod tensor;

pub use codes::{CodeMat, CodeScales};
pub use tensor::Mat;

use crate::util::rng::Pcg32;

/// Numerical floors shared with `python/compile/quantizers.py`.
pub const EPS_RANGE: f32 = 1e-20;
pub const MAX_SCALE: f32 = 1e20;

/// B = 2^bits - 1 quantization bins.
pub fn nbins(bits: f32) -> f32 {
    2f32.powf(bits) - 1.0
}

/// The gradient-quantizer family evaluated in the paper + the Table-2
/// extension formats.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum GradQuantizer {
    /// Per-tensor quantizer (§3.3) — the INT8-training baseline.
    Ptq,
    /// Per-sample quantizer (§4.1).
    Psq,
    /// Block Householder quantizer (§4.2 + Appendix D.5).
    Bhq,
    /// FP8 (E4M3) stochastic simulation — Table-2 comparison format.
    Fp8,
    /// Block floating point (HBFP-style) — Table-2 comparison format.
    Bfp,
}

impl GradQuantizer {
    pub const ALL: [GradQuantizer; 5] = [
        GradQuantizer::Ptq,
        GradQuantizer::Psq,
        GradQuantizer::Bhq,
        GradQuantizer::Fp8,
        GradQuantizer::Bfp,
    ];
    pub const PAPER: [GradQuantizer; 3] =
        [GradQuantizer::Ptq, GradQuantizer::Psq, GradQuantizer::Bhq];

    pub fn name(self) -> &'static str {
        match self {
            GradQuantizer::Ptq => "ptq",
            GradQuantizer::Psq => "psq",
            GradQuantizer::Bhq => "bhq",
            GradQuantizer::Fp8 => "fp8",
            GradQuantizer::Bfp => "bfp",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        Self::ALL.into_iter().find(|q| q.name() == s)
    }

    /// Quantize-dequantize `x` at `bits`, drawing SR noise from `rng`.
    pub fn apply(self, x: &Mat, bits: f32, rng: &mut Pcg32) -> Mat {
        let b = nbins(bits);
        match self {
            GradQuantizer::Ptq => ptq::quantize(x, b, rng).deq,
            GradQuantizer::Psq => psq::quantize(x, b, rng).deq,
            GradQuantizer::Bhq => bhq::quantize(x, b, rng).deq,
            GradQuantizer::Fp8 => fp8::quantize(x, rng),
            GradQuantizer::Bfp => bfp::quantize(x, b, 64, rng),
        }
    }

    /// Fused [`apply`]: quantize-dequantize into a caller-owned output
    /// buffer, reusing `scratch` across calls so the paper quantizers
    /// allocate nothing once warm (the native executor's hot path).
    /// Bitwise identical to `apply` — same math, same RNG draw order,
    /// same telemetry cadence (enforced by `tests/kernel_parity.rs`).
    pub fn apply_into(
        self,
        x: &Mat,
        bits: f32,
        rng: &mut Pcg32,
        scratch: &mut FusedScratch,
        out: &mut Mat,
    ) {
        let b = nbins(bits);
        match self {
            GradQuantizer::Ptq => ptq::apply_into(x, b, rng, out),
            GradQuantizer::Psq => psq::apply_into(x, b, rng, out),
            GradQuantizer::Bhq => bhq::apply_into(x, b, rng, &mut scratch.bhq, out),
            // Table-2 comparison formats are not FQT train-variant
            // quantizers, so they stay on the allocating path.
            GradQuantizer::Fp8 => *out = fp8::quantize(x, rng),
            GradQuantizer::Bfp => *out = bfp::quantize(x, b, 64, rng),
        }
    }

    /// True when this quantizer/bitwidth pair has a genuine integer-code
    /// path. PTQ/PSQ only; fractional bits give a fractional bin count B
    /// (`raw.clamp(0.0, B)` can then produce non-integer codes), and
    /// bits > 8 overflows i8 codes, so both are excluded.
    pub fn supports_codes(self, bits: f32) -> bool {
        matches!(self, GradQuantizer::Ptq | GradQuantizer::Psq)
            && bits.fract() == 0.0
            && (1.0..=8.0).contains(&bits)
    }

    /// Quantize `x` into typed i8 codes plus affine scales — the entry
    /// point for the integer GEMM path. PTQ writes `codes`/`scales` only
    /// and never materializes the dequantized matrix; PSQ additionally
    /// fills `deq` (its per-sample scales sit on the contraction axis of
    /// the weight-gradient GEMMs, which therefore stay on the f32 path —
    /// DESIGN.md §5.1). Same scale math, RNG draw order and telemetry
    /// cadence as [`Self::apply_into`].
    ///
    /// Returns `false` — bumping `quant_int_fallback_total` and leaving
    /// all outputs untouched — when no integer path exists (BHQ's
    /// Householder transform needs the f32 reconstruction; FP8/BFP are
    /// not affine-code formats; see [`Self::supports_codes`] for the
    /// bits gate). Callers fall back to [`Self::apply_into`].
    pub fn quantize_codes(
        self,
        x: &Mat,
        bits: f32,
        rng: &mut Pcg32,
        codes: &mut CodeMat,
        scales: &mut CodeScales,
        deq: &mut Mat,
    ) -> bool {
        if !self.supports_codes(bits) {
            crate::obs::quant::int_fallback(self.name());
            return false;
        }
        let b = nbins(bits);
        match self {
            GradQuantizer::Ptq => ptq::quantize_codes_into(x, b, rng, codes, scales),
            GradQuantizer::Psq => psq::quantize_codes_into(x, b, rng, codes, scales, deq),
            _ => unreachable!("supports_codes gated"),
        }
        true
    }
}

/// Reusable buffers for [`GradQuantizer::apply_into`]. One per executor
/// workspace; only BHQ needs real scratch (plan + transform buffers) —
/// PTQ/PSQ fuse into single passes over the output.
#[derive(Default)]
pub struct FusedScratch {
    bhq: bhq::Scratch,
}

/// Per-call telemetry emitted by the native quantizers alongside their
/// [`Quantized`] output and folded into `obs::quant` counters/gauges.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct QuantStats {
    /// Scalar values quantized (excludes NaN-poisoned rows).
    pub values: u64,
    /// Codes that the final clamp actually moved (out-of-range SR draws).
    pub clipped: u64,
    /// Codes that landed exactly on zero.
    pub zero_codes: u64,
    /// Rows replaced by NaN poison because the input carried NaN.
    pub poisoned_rows: u64,
    /// Exact SR variance sum p(1-p)/scale^2 (Thm-1 noise term), computed
    /// only on sampled calls.
    pub sr_variance: Option<f64>,
}

impl QuantStats {
    /// Fold another call's stats into this one (counts add; the exact
    /// variance sums when both sides sampled it, else keeps whichever
    /// side has one). Used by the segment path, which quantizes one
    /// logical payload as several reshaped blocks.
    pub fn merge(&mut self, other: &QuantStats) {
        self.values += other.values;
        self.clipped += other.clipped;
        self.zero_codes += other.zero_codes;
        self.poisoned_rows += other.poisoned_rows;
        self.sr_variance = match (self.sr_variance, other.sr_variance) {
            (Some(a), Some(b)) => Some(a + b),
            (a, b) => a.or(b),
        };
    }
}

/// Output of an affine quantizer: typed integer codes, dequantized
/// values, and the per-row bin sizes (1/scale) the Fig-4 analysis plots.
pub struct Quantized {
    pub codes: CodeMat,
    pub deq: Mat,
    /// Effective numeric width of one quantization bin, per row, in the
    /// *original* (untransformed) gradient units.
    pub row_bin_size: Vec<f32>,
}

/// Fully poisoned output, returned when a quantizer receives NaN input:
/// stochastic rounding would otherwise silently launder NaN into finite
/// garbage (`sr(NaN).max(0.0) == 0.0`), hiding a diverged gradient from
/// every downstream consumer. The f32 sides carry literal NaN; the
/// integer codes carry the per-row poison mask instead (i8 has no NaN).
pub(crate) fn poisoned(rows: usize, cols: usize, nbins: f32) -> Quantized {
    let mut codes = CodeMat::zeros(rows, cols, codes::center_for(nbins));
    codes.poison_all();
    Quantized {
        codes,
        deq: Mat::from_vec(rows, cols, vec![f32::NAN; rows * cols]),
        row_bin_size: vec![f32::NAN; rows],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outlier_matrix(n: usize, d: usize, seed: u64) -> Mat {
        // One huge row + tiny rest: the gradient structure of §4.2.
        let mut rng = Pcg32::new(seed, 0);
        let mut m = Mat::zeros(n, d);
        for i in 0..n {
            let s = if i == 0 { 10.0 } else { 0.01 };
            for v in m.row_mut(i) {
                *v = rng.normal() * s;
            }
        }
        m
    }

    /// Empirical unbiasedness + the paper's variance ordering
    /// Var[PTQ] > Var[PSQ] > Var[BHQ] on outlier-structured gradients.
    #[test]
    fn variance_ordering_and_unbiasedness() {
        let x = outlier_matrix(16, 32, 7);
        let bits = 4.0;
        let reps = 400;
        let mut var = std::collections::HashMap::new();
        for q in GradQuantizer::PAPER {
            let mut mean = vec![0.0f64; x.len()];
            let mut sq = 0.0f64;
            let mut rng = Pcg32::new(123, 9);
            for _ in 0..reps {
                let out = q.apply(&x, bits, &mut rng);
                sq += out.sq_err(&x);
                for (m, &v) in mean.iter_mut().zip(&out.data) {
                    *m += f64::from(v) / f64::from(reps as u32);
                }
            }
            let bias: f64 = mean
                .iter()
                .zip(&x.data)
                .map(|(&m, &v)| (m - f64::from(v)).abs())
                .fold(0.0, f64::max);
            // max-abs bias must be within a few empirical std errors
            assert!(bias < 0.5, "{q:?} biased: {bias}");
            var.insert(q.name(), sq / f64::from(reps as u32));
        }
        assert!(var["ptq"] > 3.0 * var["psq"], "{var:?}");
        assert!(var["psq"] > 2.0 * var["bhq"], "{var:?}");
    }

    /// Degenerate shapes (empty, zero-column, single-row) through every
    /// quantizer at normal and 1-bit widths: no panics, shape preserved,
    /// finite output.
    #[test]
    fn degenerate_shapes_never_panic() {
        let mut rng = Pcg32::new(31, 0);
        for (r, c) in [(0usize, 0usize), (0, 5), (5, 0), (1, 8)] {
            let mut x = Mat::zeros(r, c);
            for v in &mut x.data {
                *v = rng.normal();
            }
            for q in GradQuantizer::ALL {
                for bits in [1.0f32, 4.0] {
                    let out = q.apply(&x, bits, &mut rng);
                    assert_eq!((out.rows, out.cols), (r, c), "{q:?}");
                    assert!(
                        out.data.iter().all(|v| v.is_finite()),
                        "{q:?} bits {bits} shape ({r},{c})"
                    );
                }
            }
        }
    }

    /// All-zero gradients and constant tensors must reconstruct exactly
    /// (BHQ up to its reflection round-trip, ~1e-3 relative).
    #[test]
    fn all_zero_and_constant_reconstruct_exactly() {
        let mut rng = Pcg32::new(33, 0);
        let zero = Mat::zeros(4, 8);
        for q in GradQuantizer::ALL {
            for bits in [1.0f32, 5.0] {
                let out = q.apply(&zero, bits, &mut rng);
                assert!(
                    out.data.iter().all(|&v| v == 0.0),
                    "{q:?} bits {bits} not exact on zeros"
                );
            }
        }
        let constant = Mat::from_vec(3, 5, vec![2.5; 15]);
        for q in GradQuantizer::ALL {
            let tol = if q == GradQuantizer::Bhq { 1e-3 } else { 1e-6 };
            let out = q.apply(&constant, 5.0, &mut rng);
            for &v in &out.data {
                assert!((v - 2.5).abs() < tol, "{q:?}: {v} != 2.5");
            }
        }
    }

    /// Codes stay in [0, B] and integral even at bits = 1 (B = 1).
    #[test]
    fn codes_stay_in_range_at_one_bit() {
        let mut rng = Pcg32::new(35, 0);
        let mut x = Mat::zeros(4, 8);
        for v in &mut x.data {
            *v = rng.normal();
        }
        let b = nbins(1.0);
        assert_eq!(b, 1.0);
        let qp = ptq::quantize(&x, b, &mut rng);
        let qs = psq::quantize(&x, b, &mut rng);
        for (name, q) in [("ptq", &qp), ("psq", &qs)] {
            assert!(!q.codes.any_poisoned(), "{name} spuriously poisoned");
            assert_eq!(q.codes.saturated, 0, "{name} saturated in-range codes");
            for i in 0..q.codes.rows {
                for j in 0..q.codes.cols {
                    let c = q.codes.raw_at(i, j);
                    assert!(
                        (0..=b as i32).contains(&c),
                        "{name} code {c} outside [0, {b}]"
                    );
                }
            }
        }
        // BHQ codes are clipped at 0 but one-sided above (clamping the
        // top would bias the estimator): non-negative raw codes, with any
        // i8 overflow absorbed by the counted saturating store.
        let qb = bhq::quantize(&x, b, &mut rng);
        assert!(!qb.codes.any_poisoned());
        for i in 0..qb.codes.rows {
            for j in 0..qb.codes.cols {
                assert!(qb.codes.raw_at(i, j) >= 0, "bhq code negative");
            }
        }
    }

    /// Each fewer bit multiplies PTQ variance by ~4 (Eq. 10 discussion).
    /// Uses iid data: the law assumes incoherent rounding phases, which a
    /// coherent near-zero cluster (the outlier structure) violates —
    /// that regime is exactly where PSQ/BHQ win instead.
    #[test]
    fn four_x_variance_per_bit() {
        let mut rng0 = Pcg32::new(3, 5);
        let mut x = Mat::zeros(8, 64);
        for v in &mut x.data {
            *v = rng0.normal();
        }
        let reps = 300;
        let mut vars = Vec::new();
        for bits in [4.0f32, 5.0, 6.0] {
            let mut rng = Pcg32::new(5, 1);
            let mut sq = 0.0;
            for _ in 0..reps {
                sq += GradQuantizer::Ptq.apply(&x, bits, &mut rng).sq_err(&x);
            }
            vars.push(sq / f64::from(reps as u32));
        }
        for w in vars.windows(2) {
            let ratio = w[0] / w[1];
            assert!((2.5..6.0).contains(&ratio), "ratio {ratio} vars {vars:?}");
        }
    }
}
