//! Row-major f32 matrix used throughout the native quantizer stack.
//!
//! Deliberately tiny: the coordinator's tensors are gradients and
//! parameter vectors that shuttle between PJRT literals and the native
//! quantizers — not a general linear-algebra library. Hot operations
//! (row reductions, axpy) are written to autovectorize.

#[derive(Clone, Debug, Default, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Self { rows, cols, data }
    }

    pub fn from_rows(rows: &[Vec<f32>]) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, Vec::len);
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Self {
            rows: r,
            cols: c,
            data,
        }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// (min, max) of the whole tensor. Empty -> (0, 0); any NaN element
    /// -> (NaN, NaN). `f32::min`/`max` silently *drop* NaN operands, so
    /// a naive fold would hand a poisoned gradient to the quantizers as
    /// a plausible-looking finite range — propagate instead so callers
    /// can fail loudly.
    pub fn minmax(&self) -> (f32, f32) {
        minmax_slice(&self.data)
    }

    /// Per-row (min, max); NaN rows yield (NaN, NaN).
    pub fn row_minmax(&self) -> Vec<(f32, f32)> {
        (0..self.rows).map(|i| minmax_slice(self.row(i))).collect()
    }

    /// Per-row infinity norm |row|_inf (the BHQ magnitude key). NaN rows
    /// yield NaN, matching `minmax`'s propagation contract.
    pub fn row_absmax(&self) -> Vec<f32> {
        (0..self.rows)
            .map(|i| {
                let mut m = 0.0f32;
                for &v in self.row(i) {
                    if v.is_nan() {
                        return f32::NAN;
                    }
                    m = m.max(v.abs());
                }
                m
            })
            .collect()
    }

    /// Reshape in place to `rows x cols`, zero-filling any new tail.
    /// Never shrinks the backing capacity — the workspace-arena buffers
    /// (see `runtime/native.rs`) rely on this to stay allocation-free
    /// once warm.
    pub fn resize(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.resize(rows * cols, 0.0);
    }

    /// Frobenius norm squared.
    pub fn frob_sq(&self) -> f64 {
        self.data.iter().map(|&v| f64::from(v) * f64::from(v)).sum()
    }

    /// Elementwise sum of squared differences (f64 accumulator).
    pub fn sq_err(&self, other: &Mat) -> f64 {
        assert_eq!(self.data.len(), other.data.len());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| {
                let d = f64::from(a) - f64::from(b);
                d * d
            })
            .sum()
    }
}

/// (min, max) of a slice with the same NaN-propagation contract as
/// [`Mat::minmax`]; shared with the fused quantizer paths so they can
/// reduce rows in place without a `row_minmax` temporary.
pub(crate) fn minmax_slice(xs: &[f32]) -> (f32, f32) {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &v in xs {
        if v.is_nan() {
            return (f32::NAN, f32::NAN);
        }
        lo = lo.min(v);
        hi = hi.max(v);
    }
    if lo > hi {
        (0.0, 0.0)
    } else {
        (lo, hi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minmax_and_rows() {
        let m = Mat::from_vec(2, 3, vec![1.0, -2.0, 3.0, 0.0, 5.0, -1.0]);
        assert_eq!(m.minmax(), (-2.0, 5.0));
        assert_eq!(m.row_minmax(), vec![(-2.0, 3.0), (-1.0, 5.0)]);
        assert_eq!(m.row_absmax(), vec![3.0, 5.0]);
        assert_eq!(m.at(1, 1), 5.0);
    }

    #[test]
    fn from_rows_matches_from_vec() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(a, b);
    }

    #[test]
    fn sq_err_zero_on_self() {
        let m = Mat::from_vec(1, 4, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(m.sq_err(&m), 0.0);
    }

    #[test]
    #[should_panic]
    fn ragged_rows_panic() {
        Mat::from_rows(&[vec![1.0], vec![1.0, 2.0]]);
    }

    /// Regression: the seed fold dropped NaN through `f32::min`/`max`,
    /// reporting a finite (min, max) for a poisoned tensor.
    #[test]
    fn nan_propagates_through_reductions() {
        let m = Mat::from_vec(2, 3, vec![1.0, f32::NAN, 3.0, 0.0, 5.0, -1.0]);
        let (lo, hi) = m.minmax();
        assert!(lo.is_nan() && hi.is_nan());
        let rows = m.row_minmax();
        assert!(rows[0].0.is_nan() && rows[0].1.is_nan());
        // clean rows stay exact
        assert_eq!(rows[1], (-1.0, 5.0));
        let abs = m.row_absmax();
        assert!(abs[0].is_nan());
        assert_eq!(abs[1], 5.0);
    }

    #[test]
    fn empty_reductions_stay_zero() {
        let m = Mat::zeros(0, 4);
        assert_eq!(m.minmax(), (0.0, 0.0));
        assert!(m.row_minmax().is_empty());
        let wide = Mat::zeros(2, 0);
        assert_eq!(wide.row_minmax(), vec![(0.0, 0.0); 2]);
        assert_eq!(wide.row_absmax(), vec![0.0; 2]);
    }
}
