//! Metrics sinks (S14): JSONL run logs, CSV curves, markdown tables.
//!
//! Every experiment binary writes through these so tables/figures can be
//! regenerated and diffed as plain text.

use std::fs::{self, File};
use std::io::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Append-only JSONL writer (one Json object per line).
pub struct JsonlWriter {
    path: PathBuf,
    file: File,
}

impl JsonlWriter {
    pub fn create(path: impl Into<PathBuf>) -> Result<Self> {
        let path = path.into();
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        let file = File::create(&path)
            .with_context(|| format!("creating {}", path.display()))?;
        Ok(Self { path, file })
    }

    pub fn write(&mut self, j: &Json) -> Result<()> {
        let mut line = j.to_string_compact();
        line.push('\n');
        self.file.write_all(line.as_bytes())?;
        Ok(())
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

/// CSV writer with a fixed header.
pub struct CsvWriter {
    file: File,
    cols: usize,
}

impl CsvWriter {
    pub fn create(path: impl Into<PathBuf>, header: &[&str]) -> Result<Self> {
        let path: PathBuf = path.into();
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        let mut file = File::create(&path)
            .with_context(|| format!("creating {}", path.display()))?;
        writeln!(file, "{}", header.join(","))?;
        Ok(Self {
            file,
            cols: header.len(),
        })
    }

    pub fn row(&mut self, values: &[String]) -> Result<()> {
        assert_eq!(values.len(), self.cols, "csv row width mismatch");
        writeln!(self.file, "{}", values.join(","))?;
        Ok(())
    }

    pub fn rowf(&mut self, values: &[f64]) -> Result<()> {
        self.row(&values.iter().map(|v| format!("{v}")).collect::<Vec<_>>())
    }
}

/// Markdown table builder — the experiment harness prints tables in the
/// same layout as the paper's.
pub struct MarkdownTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl MarkdownTable {
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "table width mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(String::len).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let padded: Vec<String> = cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:<w$}"))
                .collect();
            format!("| {} |", padded.join(" | "))
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        out.push_str(&fmt_row(&sep, &widths));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

/// Format helpers shared by the experiment binaries.
pub fn fmt_sig(v: f64, digits: usize) -> String {
    if v == 0.0 || !v.is_finite() {
        return format!("{v}");
    }
    let mag = v.abs().log10().floor() as i32;
    let dec = (digits as i32 - 1 - mag).max(0) as usize;
    format!("{v:.dec$}")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::obj;

    #[test]
    fn jsonl_roundtrip() {
        let dir = std::env::temp_dir().join(format!("sq_metrics_{}", std::process::id()));
        let path = dir.join("log.jsonl");
        let mut w = JsonlWriter::create(&path).unwrap();
        w.write(&obj([("step", Json::from(1.0)), ("loss", Json::from(2.5))]))
            .unwrap();
        w.write(&obj([("step", Json::from(2.0)), ("loss", Json::from(2.25))]))
            .unwrap();
        drop(w);
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let j = Json::parse(lines[1]).unwrap();
        assert_eq!(j.get("loss").unwrap().as_f64(), Some(2.25));
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Regression: the old hand-rolled compact writer used `{:?}` for
    /// strings, emitting Rust debug escapes like `\u{1f600}` that no
    /// JSON parser accepts. Non-ASCII must round-trip.
    #[test]
    fn jsonl_non_ascii_strings_stay_valid_json() {
        let dir = std::env::temp_dir().join(format!("sq_jsonl_u_{}", std::process::id()));
        let path = dir.join("log.jsonl");
        let mut w = JsonlWriter::create(&path).unwrap();
        w.write(&obj([("run", Json::from("smoke 😀 é\u{1}"))])).unwrap();
        drop(w);
        let text = std::fs::read_to_string(&path).unwrap();
        let j = Json::parse(text.lines().next().unwrap()).expect("line must be valid JSON");
        assert_eq!(j.get("run").unwrap().as_str(), Some("smoke 😀 é\u{1}"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn csv_header_and_rows() {
        let dir = std::env::temp_dir().join(format!("sq_csv_{}", std::process::id()));
        let path = dir.join("curve.csv");
        let mut w = CsvWriter::create(&path, &["step", "loss"]).unwrap();
        w.rowf(&[0.0, 2.5]).unwrap();
        w.rowf(&[1.0, 2.0]).unwrap();
        drop(w);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "step,loss\n0,2.5\n1,2\n");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn markdown_table_aligns() {
        let mut t = MarkdownTable::new(&["Setting", "PTQ", "BHQ"]);
        t.row(vec!["8-bit".into(), "71.24".into(), "71.15".into()]);
        let s = t.render();
        assert!(s.contains("| Setting | PTQ   | BHQ   |"));
        assert!(s.lines().count() == 3);
    }

    #[test]
    fn fmt_sig_behaviour() {
        assert_eq!(fmt_sig(0.000123456, 3), "0.000123");
        assert_eq!(fmt_sig(123456.0, 3), "123456");
        assert_eq!(fmt_sig(0.0, 3), "0");
    }
}
