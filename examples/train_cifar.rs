//! CIFAR-style workload: the paper's §5.1 setting in miniature.
//!
//! Trains the MiniResNet ("cnn" artifact) on synthimg under four
//! regimes — exact FP32, QAT, 8-bit PTQ FQT, 5-bit BHQ FQT — and prints
//! a side-by-side comparison, the core qualitative claim of the paper:
//! 5-bit BHQ tracks QAT while low-bit PTQ degrades.
//!
//! Run: `cargo run --release --example train_cifar [-- steps]`

use anyhow::Result;

use statquant::config::TrainConfig;
use statquant::coordinator::Trainer;
use statquant::metrics::MarkdownTable;
use statquant::runtime::{Registry, Runtime};

fn main() -> Result<()> {
    let steps: u64 = std::env::args()
        .nth(1)
        .map(|s| s.parse().expect("steps must be an integer"))
        .unwrap_or(200);
    let rt = Runtime::cpu()?;
    let reg = Registry::open("artifacts")?;

    let regimes: [(&str, &str, f32); 4] = [
        ("exact FP32", "exact", 8.0),
        ("QAT (8-bit fwd)", "qat", 8.0),
        ("FQT PTQ @ 5-bit", "ptq", 5.0),
        ("FQT BHQ @ 5-bit", "bhq", 5.0),
    ];

    let mut table = MarkdownTable::new(&["regime", "eval acc (%)", "train loss", "steps/s"]);
    for (label, variant, bits) in regimes {
        let mut cfg = TrainConfig::default();
        cfg.model = "cnn".into();
        cfg.variant = variant.into();
        cfg.bits = bits;
        cfg.steps = steps;
        cfg.lr = 0.1;
        cfg.eval_every = (steps / 4).max(1);
        cfg.out_dir = "results/train_cifar".into();
        println!("[{label}] training {} steps...", cfg.steps);
        let report = Trainer::new(&rt, &reg, cfg)?.train()?;
        println!(
            "[{label}] eval acc {:.2}%, train loss {:.4}",
            100.0 * report.final_eval_acc,
            report.final_train_loss
        );
        table.row(vec![
            label.into(),
            format!("{:.2}", 100.0 * report.final_eval_acc),
            format!("{:.4}", report.final_train_loss),
            format!("{:.2}", report.steps_per_second),
        ]);
    }
    println!("\n{}", table.render());
    Ok(())
}
