//! End-to-end driver (DESIGN.md E12): train the Transformer LM for a few
//! hundred steps on the synthetic Markov corpus with fully quantized
//! training and log the loss curve, proving all layers compose:
//!
//!   Rust coordinator -> PJRT executable -> HLO containing the JAX model
//!   -> whose every linear layer runs the Pallas qmatmul kernel and whose
//!   backward runs the Pallas sr_quant kernel under the BHQ transform.
//!
//! The curve must descend from ~ln(256) ~ 5.55 (uniform) toward the
//! Markov chain's entropy floor; the run is recorded in EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example train_transformer [-- steps [variant [bits]]]`

use anyhow::Result;

use statquant::config::TrainConfig;
use statquant::coordinator::Trainer;
use statquant::data::markov::{Markov, MarkovConfig};
use statquant::runtime::{Registry, Runtime};

fn main() -> Result<()> {
    let mut args = std::env::args().skip(1);
    let steps: u64 = args.next().map(|s| s.parse().unwrap()).unwrap_or(300);
    let variant: String = args.next().unwrap_or_else(|| "bhq".into());
    let bits: f32 = args.next().map(|s| s.parse().unwrap()).unwrap_or(5.0);

    let rt = Runtime::cpu()?;
    let reg = Registry::open("artifacts")?;

    let mut cfg = TrainConfig::default();
    cfg.model = "transformer".into();
    cfg.variant = variant.clone();
    cfg.bits = bits;
    cfg.steps = steps;
    cfg.lr = 0.05;
    cfg.eval_every = (steps / 10).max(1);
    cfg.out_dir = "results/train_transformer".into();

    let floor = Markov::new(MarkovConfig::default()).entropy_floor();
    println!(
        "transformer LM | {} @ {} bits | {} steps | loss floor ~ {:.3} nats",
        variant, bits, steps, floor
    );

    let mut trainer = Trainer::new(&rt, &reg, cfg)?;
    let report = trainer.train()?;

    println!("\nloss curve:");
    let stride = (report.curve.len() / 15).max(1);
    for (step, loss) in report.curve.iter().step_by(stride) {
        let bar = "#".repeat(((loss - floor).max(0.0) * 18.0).min(70.0) as usize);
        println!("  step {step:>4}  loss {loss:.4}  {bar}");
    }
    println!(
        "\nfinal: train loss {:.4} (floor {:.3}), eval loss {:.4}, \
         eval token acc {:.2}%, {:.2} steps/s over {:.1}s",
        report.final_train_loss,
        floor,
        report.final_eval_loss,
        100.0 * report.final_eval_acc,
        report.steps_per_second,
        report.wall_seconds
    );
    let start = report.curve.first().map(|c| c.1).unwrap_or(f64::NAN);
    assert!(
        report.final_train_loss < start - 0.5,
        "loss must descend substantially (start {start:.3})"
    );
    println!("train_transformer OK");
    Ok(())
}
