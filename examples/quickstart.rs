//! Quickstart: the smallest end-to-end use of the StatQuant stack.
//!
//! Loads the MLP fully-quantized-training artifact (built once by
//! `make artifacts`), trains it on the synthetic image task with a 5-bit
//! BHQ gradient, and prints the loss curve — all from Rust, no Python on
//! the path.
//!
//! Run: `cargo run --release --example quickstart`

use anyhow::Result;

use statquant::config::TrainConfig;
use statquant::coordinator::Trainer;
use statquant::runtime::{Registry, Runtime};

fn main() -> Result<()> {
    let rt = Runtime::cpu()?;
    let reg = Registry::open("artifacts")?;

    let mut cfg = TrainConfig::default();
    cfg.model = "mlp".into();
    cfg.variant = "bhq".into(); // the paper's block Householder quantizer
    cfg.bits = 5.0; // headline setting: 5-bit gradients
    cfg.steps = 120;
    cfg.lr = 0.05;
    cfg.eval_every = 20;
    cfg.out_dir = "results/quickstart".into();

    println!(
        "training {} with {}-bit {} gradients ({} steps)...",
        cfg.model, cfg.bits, cfg.variant, cfg.steps
    );
    let mut trainer = Trainer::new(&rt, &reg, cfg)?;
    let report = trainer.train()?;

    println!("\nloss curve (every 20 steps):");
    for (step, loss) in report.curve.iter().step_by(20) {
        let bar = "#".repeat((loss * 20.0).min(60.0) as usize);
        println!("  step {step:>4}  loss {loss:.4}  {bar}");
    }
    println!(
        "\nfinal: train loss {:.4}, eval acc {:.2}% ({:.1} steps/s)",
        report.final_train_loss,
        100.0 * report.final_eval_acc,
        report.steps_per_second
    );
    assert!(
        report.final_eval_acc > 0.5,
        "5-bit BHQ training should comfortably learn the task"
    );
    println!("quickstart OK");
    Ok(())
}
