//! Variance probe walkthrough: measure, per quantizer and bitwidth, the
//! gradient variance that Theorem 2 bounds — the quantity that drives
//! every accuracy result in the paper.
//!
//! Demonstrates the probe ABI directly (load the probe artifact, feed a
//! fixed batch, Welford over SR seeds) and prints the variance matrix
//! plus the "BHQ ~ PTQ - 3 bits" equivalence the paper reports.
//!
//! Run: `cargo run --release --example variance_probe [-- model]`

use anyhow::Result;

use statquant::config::TrainConfig;
use statquant::coordinator::make_dataset;
use statquant::experiments::common::warm_params;
use statquant::metrics::{fmt_sig, MarkdownTable};
use statquant::runtime::{Executor, Registry, Runtime, StepKind};
use statquant::stats::GradVarianceProbe;

fn main() -> Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "mlp".into());
    let rt = Runtime::cpu()?;
    let reg = Registry::open("artifacts")?;

    let mut cfg = TrainConfig::default();
    cfg.model = model.clone();
    cfg.out_dir = "results/variance_probe".into();
    // a short warmup makes gradients realistically sparse (high train acc)
    let params = warm_params(&rt, &reg, &cfg, 80)?;

    let meta = reg.meta(&model, "qat", StepKind::Probe)?;
    let dataset = make_dataset(
        &cfg,
        &meta.input_shape,
        if model == "transformer" { "markov" } else { "synthimg" },
    );
    let batch = dataset.batch(2_000_000);

    let bits = [3.0f32, 4.0, 5.0, 6.0, 7.0, 8.0];
    let mut table = MarkdownTable::new(&["bits", "PTQ", "PSQ", "BHQ"]);
    let mut curves: Vec<(String, Vec<f64>)> = Vec::new();
    for q in ["ptq", "psq", "bhq"] {
        let exec = rt.executor(reg.meta(&model, q, StepKind::Probe)?)?;
        let probe = GradVarianceProbe::new(&exec);
        let mut vs = Vec::new();
        for &b in &bits {
            let rep = probe.quantization_variance(&params, &batch.x, &batch.y, b, 10, 3)?;
            vs.push(rep.quant_variance);
        }
        curves.push((q.to_string(), vs));
    }
    for (i, &b) in bits.iter().enumerate() {
        table.row(vec![
            format!("{b}"),
            fmt_sig(curves[0].1[i], 3),
            fmt_sig(curves[1].1[i], 3),
            fmt_sig(curves[2].1[i], 3),
        ]);
    }
    println!("\nquantization variance Var[grad | batch]:\n{}", table.render());

    // the paper's equivalence: how many bits does BHQ save vs PTQ?
    // find, for each bits b, the PTQ bitwidth with matching variance.
    let ptq = &curves[0].1;
    let bhq = &curves[2].1;
    let mut saved = Vec::new();
    for (i, &b) in bits.iter().enumerate() {
        // interpolate log-variance of PTQ at bhq[i]
        let target = bhq[i].max(1e-300).log2();
        let mut equiv = None;
        for j in 0..bits.len() - 1 {
            let (y0, y1) = (ptq[j].max(1e-300).log2(), ptq[j + 1].max(1e-300).log2());
            if (y1 - target) * (y0 - target) <= 0.0 {
                let t = (target - y0) / (y1 - y0);
                equiv = Some(f64::from(bits[j]) + t * f64::from(bits[j + 1] - bits[j]));
                break;
            }
        }
        if let Some(e) = equiv {
            saved.push(e - f64::from(b));
            println!("BHQ@{b} bits ~ PTQ@{e:.2} bits (saves {:.2} bits)", e - f64::from(b));
        }
    }
    if !saved.is_empty() {
        let avg = saved.iter().sum::<f64>() / saved.len() as f64;
        println!("\naverage bits saved by BHQ over PTQ: {avg:.2} (paper: ~3)");
    }
    Ok(())
}
