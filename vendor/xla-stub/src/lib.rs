//! Compile-time stand-in for the `xla` (PJRT) bindings.
//!
//! The real crate links `xla_extension`, which is not available in the
//! offline build image. This stub mirrors the small API surface that
//! `statquant::runtime::pjrt` uses so `cargo build --features pjrt`
//! type-checks everywhere. [`Literal`] is fully functional (host-side
//! data only); the client/compile/execute entry points return a runtime
//! error directing the user to link the real bindings.
//!
//! To run against real PJRT, point the `xla` path dependency in
//! `rust/Cargo.toml` at the actual bindings crate — no `statquant`
//! source changes are needed.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

/// Error type matching the real crate's role in `?` conversions.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what} requires the real xla/PJRT bindings (this build links the offline stub; \
         point the `xla` path dependency at the real crate)"
    )))
}

/// Element dtypes we can cross the host ABI with. The extra variants
/// exist so downstream `match` arms with a catch-all stay reachable.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    F64,
    S64,
    Pred,
    Bf16,
}

#[derive(Clone, Debug)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Host-side literal: scalars, dense arrays, and tuples.
#[derive(Clone, Debug)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

/// Rust scalar types that map onto an XLA element type.
pub trait NativeType: Copy {
    const TY: ElementType;
    #[doc(hidden)]
    fn wrap(vals: Vec<Self>) -> Data;
    #[doc(hidden)]
    fn unwrap(data: &Data) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn wrap(vals: Vec<Self>) -> Data {
        Data::F32(vals)
    }
    fn unwrap(data: &Data) -> Option<Vec<Self>> {
        match data {
            Data::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn wrap(vals: Vec<Self>) -> Data {
        Data::I32(vals)
    }
    fn unwrap(data: &Data) -> Option<Vec<Self>> {
        match data {
            Data::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl Literal {
    /// Rank-0 scalar.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal {
            data: T::wrap(vec![v]),
            dims: vec![],
        }
    }

    /// Rank-1 array.
    pub fn vec1<T: NativeType>(vs: &[T]) -> Literal {
        Literal {
            data: T::wrap(vs.to_vec()),
            dims: vec![vs.len() as i64],
        }
    }

    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal {
            data: Data::Tuple(parts),
            dims: vec![],
        }
    }

    pub fn element_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::Tuple(t) => t.len(),
        }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let numel: i64 = dims.iter().product();
        if numel as usize != self.element_count() {
            return Err(Error(format!(
                "reshape to {dims:?} ({} elements) from {} elements",
                numel,
                self.element_count()
            )));
        }
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    pub fn ty(&self) -> Result<ElementType> {
        match &self.data {
            Data::F32(_) => Ok(ElementType::F32),
            Data::I32(_) => Ok(ElementType::S32),
            Data::Tuple(_) => Err(Error("tuple literal has no element type".into())),
        }
    }

    pub fn shape_dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data)
            .ok_or_else(|| Error(format!("literal is not a {:?} array", T::TY)))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.data {
            Data::Tuple(t) => Ok(t.clone()),
            _ => Err(Error("literal is not a tuple".into())),
        }
    }
}

/// Parsed HLO module (opaque in the stub).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<Self> {
        // Reading the file is cheap and gives callers the same
        // missing-artifact error surface as the real crate.
        std::fs::read_to_string(path.as_ref())
            .map_err(|e| Error(format!("reading {}: {e}", path.as_ref().display())))?;
        Ok(HloModuleProto(()))
    }
}

pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation(())
    }
}

/// Device buffer handle (never constructible via the stub's client).
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T: Borrow<Literal>>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

pub struct PjRtClient(());

impl PjRtClient {
    /// Errors in the stub so `statquant::Runtime::cpu()` falls back to
    /// the native interpreter instead of failing later at compile time.
    pub fn cpu() -> Result<Self> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_scalar_and_vec() {
        let s = Literal::scalar(2.5f32);
        assert_eq!(s.element_count(), 1);
        assert_eq!(s.ty().unwrap(), ElementType::F32);
        let v = Literal::vec1(&[1i32, 2, 3]);
        assert_eq!(v.to_vec::<i32>().unwrap(), vec![1, 2, 3]);
        assert!(v.to_vec::<f32>().is_err());
    }

    #[test]
    fn literal_reshape_checks_numel() {
        let v = Literal::vec1(&[0.0f32; 6]);
        assert_eq!(v.reshape(&[2, 3]).unwrap().shape_dims(), &[2, 3]);
        assert!(v.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn tuple_roundtrip() {
        let t = Literal::tuple(vec![Literal::scalar(1.0f32), Literal::scalar(2i32)]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(t.ty().is_err());
    }

    #[test]
    fn client_is_unavailable() {
        assert!(PjRtClient::cpu().is_err());
    }
}
