# L2 facade: build the step functions that cross the Rust <-> HLO ABI.
#
# Every artifact is one jitted function over *flat f32 state vectors*
# (jax.flatten_util.ravel_pytree at trace time), so the Rust runtime never
# needs to know the parameter pytree:
#
#   train:   (params[P], momentum[P], x, y, seed, lr, bits)
#               -> (params'[P], momentum'[P], loss, acc)
#   probe:   (params[P], x, y, seed, bits) -> (loss, grad[P])
#   eval:    (params[P], x, y)             -> (loss, acc)
#   actgrad: (params[P], x, y, seed)       -> dL/dH_probe  (QAT graph)
#
# `bits` is a runtime scalar (B = 2^bits - 1 in-graph): one artifact per
# (model, variant) serves the whole bitwidth sweep. The optimizer
# (momentum SGD, the paper's setting) is fused into the train step so the
# Rust hot loop is a single PJRT execute per step.
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

from . import quantizers as Q
from .models import cnn, mlp, transformer

MODELS = {
    "mlp": (mlp, mlp.Config()),
    "cnn": (cnn, cnn.Config()),
    "resnet": (cnn, cnn.RESNET),
    "transformer": (transformer, transformer.Config()),
}

MOMENTUM = 0.9  # paper Appendix E (CIFAR10: 0.9; ImageNet: 0.875)


@dataclass
class BuiltModel:
    """A model instance plus its flat-parameter codec and step functions."""

    name: str
    cfg: object
    mod: object
    qcfg: Q.QuantConfig
    params0_flat: np.ndarray
    unravel: object

    @property
    def n_params(self):
        return int(self.params0_flat.size)


def build(model_name: str, variant: str, seed: int = 0) -> BuiltModel:
    mod, cfg = MODELS[model_name]
    qcfg = Q.QuantConfig(kind=variant)
    rng = np.random.default_rng(seed)
    params = mod.init(rng, cfg)
    flat, unravel = ravel_pytree(params)
    return BuiltModel(
        name=model_name,
        cfg=cfg,
        mod=mod,
        qcfg=qcfg,
        params0_flat=np.asarray(flat, np.float32),
        unravel=unravel,
    )


def _xy_specs(cfg):
    """ShapeDtypeStructs for a data batch (x, y)."""
    xdt = jnp.float32 if cfg.input_dtype == "f32" else jnp.int32
    x = jax.ShapeDtypeStruct(cfg.input_shape, xdt)
    if cfg.name == "transformer":
        y = jax.ShapeDtypeStruct(cfg.input_shape, jnp.int32)
    else:
        y = jax.ShapeDtypeStruct((cfg.input_shape[0],), jnp.int32)
    return x, y


def make_train_step(bm: BuiltModel):
    """Fused fwd + bwd + momentum-SGD step over flat state."""

    def step(flat_p, flat_m, x, y, seed, lr, bits):
        params = bm.unravel(flat_p)

        def loss(p):
            return bm.mod.loss_fn(p, x, y, seed, bits, bm.qcfg, bm.cfg)

        (l, acc), grads = jax.value_and_grad(loss, has_aux=True)(params)
        flat_g, _ = ravel_pytree(grads)
        new_m = MOMENTUM * flat_m + flat_g
        new_p = flat_p - lr * new_m
        return new_p, new_m, l, acc

    return step


def make_probe_step(bm: BuiltModel):
    """Gradient probe: same graph as train minus the update; Rust runs it
    K times with different seeds to Welford-estimate Var[grad | batch]."""

    def step(flat_p, x, y, seed, bits):
        params = bm.unravel(flat_p)

        def loss(p):
            l, _ = bm.mod.loss_fn(p, x, y, seed, bits, bm.qcfg, bm.cfg)
            return l

        l, grads = jax.value_and_grad(loss)(params)
        flat_g, _ = ravel_pytree(grads)
        return l, flat_g

    return step


def make_eval_step(bm: BuiltModel):
    def step(flat_p, x, y):
        params = bm.unravel(flat_p)
        l, acc = bm.mod.loss_fn(
            params, x, y, jnp.zeros(()), jnp.asarray(8.0), bm.qcfg, bm.cfg
        )
        return l, acc

    return step


def make_actgrad_step(bm: BuiltModel):
    """Activation-gradient probe for the Fig-4 histogram experiment:
    returns dL/dH at the model's probe layer, flattened to the paper's
    (N, D) per-sample view. Built on the QAT graph (deterministic
    backward), so it captures the gradient *entering* Q_b; the Rust-native
    quantizers then bin it per Fig 4."""

    def step(flat_p, x, y, seed):
        params = bm.unravel(flat_p)
        shape = bm.mod.probe_shape(bm.cfg)

        def loss(tap):
            l, _ = bm.mod.loss_fn(
                params, x, y, seed, jnp.asarray(8.0), bm.qcfg, bm.cfg,
                probe_tap=tap,
            )
            return l

        tap0 = jnp.zeros(shape, jnp.float32)
        g = jax.grad(loss)(tap0)
        n = bm.cfg.input_shape[0]
        return g.reshape(n, -1)

    return step


def lower_step(bm: BuiltModel, kind: str):
    """jit + lower one step function with the artifact's example args."""
    p = jax.ShapeDtypeStruct((bm.n_params,), jnp.float32)
    x, y = _xy_specs(bm.cfg)
    s = jax.ShapeDtypeStruct((), jnp.float32)
    if kind == "train":
        fn, args = make_train_step(bm), (p, p, x, y, s, s, s)
        donate = (0, 1)
    elif kind == "probe":
        fn, args = make_probe_step(bm), (p, x, y, s, s)
        donate = ()
    elif kind == "eval":
        fn, args = make_eval_step(bm), (p, x, y)
        donate = ()
    elif kind == "actgrad":
        fn, args = make_actgrad_step(bm), (p, x, y, s)
        donate = ()
    else:
        raise ValueError(kind)
    # keep_unused: exact/qat variants ignore seed/bits, but the ABI (and
    # the Rust runtime) passes them for every variant — jit would
    # otherwise prune the parameters out of the lowered HLO.
    return jax.jit(fn, donate_argnums=donate, keep_unused=True).lower(*args), args
