# L2 model zoo: MLP, MiniCNN/MiniResNet (ResNet stand-ins), Transformer LM.
from . import cnn, mlp, transformer  # noqa: F401
