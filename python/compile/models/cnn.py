# MiniResNet — the ResNet-family stand-in (DESIGN.md §4 substitutions).
#
# Pre-activation residual CNN in the ResNet-v2 style [He et al. '16] used
# by the paper's CIFAR10 experiments, scaled to run in minutes on the CPU
# PJRT backend. Two named configs:
#   "cnn"    — ResNet18 stand-in: 16x16 input, 1 block/stage, widths 16/32
#   "resnet" — ResNet50/56 stand-in: deeper + wider + 32x32 input
#
# Every convolution is im2col + the quantized `qlinear` GEMM, so the FQT
# backward (bifurcated Q_b1/Q_b2) applies to every conv exactly as the
# paper prescribes; BN inputs/gradients are quantized through `qidentity`
# taps ("we quantize the inputs and gradients of batch normalization
# layers"). The per-sample gradient view for PSQ/BHQ reshapes the
# (N*OH*OW, C) conv gradient to (N, OH*OW*C) — the paper's N x D^(l)
# layout.
from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from ..layers import LayerIds, make_qidentity, make_qlinear, ste_quantize
from .common import batchnorm, cross_entropy, im2col


@dataclass(frozen=True)
class Config:
    name: str = "cnn"
    image: int = 16
    channels: int = 3
    widths: tuple = (16, 32)
    blocks_per_stage: int = 1
    classes: int = 10
    batch: int = 32

    @property
    def input_shape(self):
        return (self.batch, self.image, self.image, self.channels)

    @property
    def input_dtype(self):
        return "f32"


RESNET = Config(
    name="resnet", image=32, widths=(16, 32, 64), blocks_per_stage=2, batch=32
)


def _conv_init(rng, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    w = rng.normal(0.0, np.sqrt(2.0 / fan_in), (kh * kw * cin, cout))
    return jnp.asarray(w.astype(np.float32))


def _bn_init(c):
    return {
        "gamma": jnp.ones((c,), jnp.float32),
        "beta": jnp.zeros((c,), jnp.float32),
    }


def init(rng: np.random.Generator, cfg: Config):
    params = {"stem": _conv_init(rng, 3, 3, cfg.channels, cfg.widths[0])}
    stages = []
    cin = cfg.widths[0]
    for w in cfg.widths:
        blocks = []
        for b in range(cfg.blocks_per_stage):
            c0 = cin if b == 0 else w
            blk = {
                "bn1": _bn_init(c0),
                "conv1": _conv_init(rng, 3, 3, c0, w),
                "bn2": _bn_init(w),
                "conv2": _conv_init(rng, 3, 3, w, w),
            }
            if c0 != w:
                blk["proj"] = _conv_init(rng, 1, 1, c0, w)
            blocks.append(blk)
        stages.append(blocks)
        cin = w
    params["stages"] = stages
    params["bn_out"] = _bn_init(cfg.widths[-1])
    fc = rng.normal(0.0, np.sqrt(1.0 / cfg.widths[-1]), (cfg.widths[-1], cfg.classes))
    params["fc_w"] = jnp.asarray(fc.astype(np.float32))
    params["fc_b"] = jnp.zeros((cfg.classes,), jnp.float32)
    return params


def _conv(ids, qcfg, cfg, x, w, seed, bits, kh, kw, stride, pad):
    """Quantized convolution: Q_f(H) -> im2col -> qlinear GEMM.

    Q_f is applied to the activation *before* patch extraction: the patch
    matrix duplicates every pixel ~kh*kw times, and quantizing first gives
    bit-identical patches at 1/(kh*kw) the quantization work."""
    n = x.shape[0]
    if qcfg.quantizes_fwd:
        fwd_bins = float(2**qcfg.fwd_bits - 1)
        x = ste_quantize(x.reshape(n, -1), fwd_bins).reshape(x.shape)
    patches, (oh, ow) = im2col(x, kh, kw, stride, pad)
    qlin = make_qlinear(ids.fresh(), qcfg, sample_count=n, h_prequantized=True)
    out = qlin(patches, w, seed, bits)
    return out.reshape(n, oh, ow, -1)


def apply(params, x, seed, bits, qcfg, cfg: Config, probe_tap=None):
    """Forward -> logits (N, classes). probe_tap (optional zeros of
    probe_shape) is added before the final stage's first conv; its
    gradient is the Fig-4 activation gradient."""
    ids = LayerIds()
    h = _conv(ids, qcfg, cfg, x, params["stem"], seed, bits, 3, 3, 1, 1)
    n_stages = len(params["stages"])
    for si, blocks in enumerate(params["stages"]):
        stride = 1 if si == 0 else 2
        if probe_tap is not None and si == n_stages - 1:
            h = h + probe_tap.reshape(h.shape)
        for bi, blk in enumerate(blocks):
            s = stride if bi == 0 else 1
            qid1 = make_qidentity(ids.fresh(), qcfg, sample_count=h.shape[0])
            pre = batchnorm(blk["bn1"], qid1(h, seed, bits))
            pre = jnp.maximum(pre, 0.0)
            out = _conv(ids, qcfg, cfg, pre, blk["conv1"], seed, bits, 3, 3, s, 1)
            qid2 = make_qidentity(ids.fresh(), qcfg, sample_count=out.shape[0])
            out = batchnorm(blk["bn2"], qid2(out, seed, bits))
            out = jnp.maximum(out, 0.0)
            out = _conv(ids, qcfg, cfg, out, blk["conv2"], seed, bits, 3, 3, 1, 1)
            if "proj" in blk:
                h = _conv(ids, qcfg, cfg, pre, blk["proj"], seed, bits, 1, 1, s, 0)
            h = h + out
    h = jnp.maximum(batchnorm(params["bn_out"], h), 0.0)
    h = jnp.mean(h, axis=(1, 2))  # global average pool
    qlin = make_qlinear(ids.fresh(), qcfg, sample_count=h.shape[0])
    return qlin(h, params["fc_w"], seed, bits) + params["fc_b"]


def probe_shape(cfg: Config):
    """Activation shape entering the last stage (pre-downsample)."""
    n_stages = len(cfg.widths)
    # spatial after stage i>0 halves; before last stage there have been
    # n_stages-2 halvings past the stem stage.
    size = cfg.image // (2 ** max(n_stages - 2, 0))
    return (cfg.batch, size, size, cfg.widths[n_stages - 2])


def loss_fn(params, x, y, seed, bits, qcfg, cfg: Config, probe_tap=None):
    logits = apply(params, x, seed, bits, qcfg, cfg, probe_tap)
    return cross_entropy(logits, y)
