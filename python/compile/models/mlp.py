# MLP classifier — the smallest member of the model zoo.
#
# Used by the quickstart example, the fast unit/integration tests, and the
# Thm-1/Eq-10 statistical validation experiments where thousands of probe
# steps are required. Every linear layer routes through the quantized
# `qlinear` primitive, so even this model exercises the full FQT stack.
from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from ..layers import LayerIds, make_qlinear
from .common import cross_entropy


@dataclass(frozen=True)
class Config:
    name: str = "mlp"
    in_dim: int = 64
    hidden: tuple = (128, 128)
    classes: int = 10
    batch: int = 64

    @property
    def input_shape(self):
        return (self.batch, self.in_dim)

    @property
    def input_dtype(self):
        return "f32"


def init(rng: np.random.Generator, cfg: Config):
    """He-initialized parameters as a pytree of f32 arrays."""
    dims = (cfg.in_dim,) + tuple(cfg.hidden) + (cfg.classes,)
    layers = []
    for din, dout in zip(dims[:-1], dims[1:]):
        w = rng.normal(0.0, np.sqrt(2.0 / din), (din, dout)).astype(np.float32)
        b = np.zeros((dout,), np.float32)
        layers.append({"w": jnp.asarray(w), "b": jnp.asarray(b)})
    return {"layers": layers}


def apply(params, x, seed, bits, qcfg, cfg: Config, probe_tap=None):
    """Forward pass -> logits (N, classes).

    probe_tap: optional zeros tensor added at the penultimate activation;
    its gradient is the activation gradient the Fig-4 experiment probes.
    """
    ids = LayerIds()
    h = x
    n_layers = len(params["layers"])
    for i, layer in enumerate(params["layers"]):
        qlin = make_qlinear(ids.fresh(), qcfg, sample_count=cfg.batch)
        if probe_tap is not None and i == n_layers - 1:
            h = h + probe_tap
        h = qlin(h, layer["w"], seed, bits) + layer["b"]
        if i < n_layers - 1:
            h = jnp.maximum(h, 0.0)
    return h


def probe_shape(cfg: Config):
    """Shape of the activation the Fig-4 histogram experiment taps."""
    return (cfg.batch, cfg.hidden[-1])


def loss_fn(params, x, y, seed, bits, qcfg, cfg: Config, probe_tap=None):
    """Mean softmax cross-entropy + accuracy aux."""
    logits = apply(params, x, seed, bits, qcfg, cfg, probe_tap)
    return cross_entropy(logits, y)
