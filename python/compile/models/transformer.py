# Decoder-only Transformer LM — the machine-translation stand-in
# (paper §5.4: IWSLT14 En-De with a fairseq transformer; DESIGN.md §4
# substitutes a synthetic Markov corpus + next-token LM — the gradient
# row-skew that separates PTQ/PSQ/BHQ arises the same way from easy vs
# hard tokens).
#
# Following the paper's MT setup, "we only quantize all the linear
# layers": QKV/out projections and both FFN GEMMs route through qlinear;
# embeddings, layernorm, and the attention softmax stay f32.
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..layers import LayerIds, make_qlinear
from .common import cross_entropy, layernorm


@dataclass(frozen=True)
class Config:
    name: str = "transformer"
    vocab: int = 256
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 2
    d_ff: int = 512
    seq: int = 64
    batch: int = 16

    @property
    def input_shape(self):
        return (self.batch, self.seq)

    @property
    def input_dtype(self):
        return "i32"

    @property
    def n_params_estimate(self):
        per_block = 4 * self.d_model**2 + 2 * self.d_model * self.d_ff
        return (
            self.vocab * self.d_model * 2
            + self.seq * self.d_model
            + self.n_layers * per_block
        )


def _lin_init(rng, din, dout, scale=None):
    s = scale or np.sqrt(1.0 / din)
    return jnp.asarray(rng.normal(0.0, s, (din, dout)).astype(np.float32))


def _ln_init(d):
    return {"gamma": jnp.ones((d,), jnp.float32), "beta": jnp.zeros((d,), jnp.float32)}


def init(rng: np.random.Generator, cfg: Config):
    d = cfg.d_model
    params = {
        "tok_emb": _lin_init(rng, cfg.vocab, d, 0.02),
        "pos_emb": _lin_init(rng, cfg.seq, d, 0.02),
        "blocks": [],
        "ln_f": _ln_init(d),
        "head": _lin_init(rng, d, cfg.vocab),
    }
    for _ in range(cfg.n_layers):
        params["blocks"].append(
            {
                "ln1": _ln_init(d),
                "wqkv": _lin_init(rng, d, 3 * d),
                "wo": _lin_init(rng, d, d),
                "ln2": _ln_init(d),
                "wff1": _lin_init(rng, d, cfg.d_ff),
                "wff2": _lin_init(rng, cfg.d_ff, d, np.sqrt(1.0 / cfg.d_ff)),
            }
        )
    return params


def apply(params, tokens, seed, bits, qcfg, cfg: Config, probe_tap=None):
    """tokens (B, T) i32 -> logits (B, T, V)."""
    ids = LayerIds()
    b, t = tokens.shape
    d, nh = cfg.d_model, cfg.n_heads
    dh = d // nh

    h = params["tok_emb"][tokens] + params["pos_emb"][None, :, :]
    mask = jnp.tril(jnp.ones((t, t), jnp.float32))
    neg = jnp.asarray(-1e9, jnp.float32)

    n_blocks = len(params["blocks"])
    for li, blk in enumerate(params["blocks"]):
        if probe_tap is not None and li == n_blocks - 1:
            h = h + probe_tap.reshape(h.shape)
        x = layernorm(blk["ln1"], h)
        x2 = x.reshape(b * t, d)
        qkv_lin = make_qlinear(ids.fresh(), qcfg, sample_count=b)
        qkv = qkv_lin(x2, blk["wqkv"], seed, bits).reshape(b, t, 3, nh, dh)
        q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
        # (b, nh, t, dh)
        q = q.transpose(0, 2, 1, 3)
        k = k.transpose(0, 2, 1, 3)
        v = v.transpose(0, 2, 1, 3)
        att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(dh)
        att = jnp.where(mask[None, None] > 0, att, neg)
        att = jax.nn.softmax(att, axis=-1)
        ctx = jnp.einsum("bhqk,bhkd->bhqd", att, v)
        ctx = ctx.transpose(0, 2, 1, 3).reshape(b * t, d)
        out_lin = make_qlinear(ids.fresh(), qcfg, sample_count=b)
        h = h + out_lin(ctx, blk["wo"], seed, bits).reshape(b, t, d)

        x = layernorm(blk["ln2"], h).reshape(b * t, d)
        ff1 = make_qlinear(ids.fresh(), qcfg, sample_count=b)
        ff2 = make_qlinear(ids.fresh(), qcfg, sample_count=b)
        y = jax.nn.gelu(ff1(x, blk["wff1"], seed, bits))
        h = h + ff2(y, blk["wff2"], seed, bits).reshape(b, t, d)

    h = layernorm(params["ln_f"], h).reshape(b * t, d)
    head = make_qlinear(ids.fresh(), qcfg, sample_count=b)
    logits = head(h, params["head"], seed, bits)
    return logits.reshape(b, t, cfg.vocab)


def probe_shape(cfg: Config):
    return (cfg.batch, cfg.seq * cfg.d_model)


def loss_fn(params, x, y, seed, bits, qcfg, cfg: Config, probe_tap=None):
    """Next-token CE: y is x shifted by one (prepared by the data layer)."""
    logits = apply(params, x, seed, bits, qcfg, cfg, probe_tap)
    return cross_entropy(logits, y)
