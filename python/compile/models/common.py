# Shared model-zoo helpers: losses, norms, im2col convolution plumbing.
import jax
import jax.numpy as jnp


def cross_entropy(logits, y):
    """Mean softmax cross-entropy over integer labels + accuracy aux."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll)
    acc = jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))
    return loss, acc


def im2col(x, kh, kw, stride, pad):
    """Extract conv patches as a GEMM-ready matrix.

    x: (N, H, W, C). Returns (patches (N*OH*OW, kh*kw*C), (OH, OW)).
    The kernel loop is a static Python unroll (kh*kw slices), so the
    lowered HLO is a fixed concatenate — no gather, no dynamic shapes.
    Weight layout convention: (kh*kw*C, Cout) with (i, j, c) varying in
    the same row-major order as the concatenation below.
    """
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    n, h, w, c = xp.shape
    oh = (h - kh) // stride + 1
    ow = (w - kw) // stride + 1
    cols = []
    for i in range(kh):
        for j in range(kw):
            cols.append(
                xp[:, i : i + oh * stride : stride, j : j + ow * stride : stride, :]
            )
    patches = jnp.concatenate(cols, axis=-1)  # (N, OH, OW, kh*kw*C)
    return patches.reshape(n * oh * ow, kh * kw * c), (oh, ow)


def batchnorm(params, x, eps=1e-5):
    """Batch-statistics normalization over (N, H, W) per channel.

    Batch stats are used at both train and eval time (DESIGN.md §4
    substitution: no running-statistics state crosses the Rust ABI; eval
    batches share the train batch size, so the estimator is consistent).
    """
    mean = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
    xn = (x - mean) * jax.lax.rsqrt(var + eps)
    return xn * params["gamma"] + params["beta"]


def layernorm(params, x, eps=1e-5):
    """LayerNorm over the trailing feature axis (transformer blocks)."""
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    xn = (x - mean) * jax.lax.rsqrt(var + eps)
    return xn * params["gamma"] + params["beta"]
