# L2: the paper's gradient quantizers (Sections 3.3 and 4, Appendix D).
#
# All quantizers share the affine form of Eq. (11):
#
#     Q_b(X) = S^{-1} SR( S (X - 1 z) ) + 1 z
#
# with SR = stochastic rounding (unbiased), and differ only in the scale
# matrix S:
#
#   PTQ  (per-tensor, §3.3):  S = s I,            s = B / R(X)
#   PSQ  (per-sample,  §4.1): S = diag(s_1..s_N), s_i = B / R(x_i)
#   BHQ  (block Householder, §4.2 + App. D.5):
#        S = Q diag(s),  Q = blockdiag of I - 2 n n^T / |n|^2,
#        n = 1/sqrt(m) - e_leader per row-group; groups built by the
#        Appendix-D.5 heuristic (sort rows by |row|_inf, sweep G, group
#        sizes proportional to leader magnitude, argmin variance proxy).
#
# Extension formats for the Table-2 comparison (DESIGN.md E6): FP8-sim
# (E4M3/E5M2 with stochastic rounding) and BFP (block floating point).
#
# Every quantizer is an *unbiased* stochastic estimator of its input —
# deterministic affine maps composed with unbiased SR (Theorem 1's only
# requirement on Q_b). The per-element SR hot path runs in the L1 Pallas
# kernel (kernels/sr_quant.py); reductions / sorting / group construction
# stay in jnp (they are O(N log N) on N = batch rows, negligible next to
# the O(N D) rounding pass — the paper's §4.3 measures the same split).
import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels import qmatmul, rn_quant, sr_quant

# Numerical floors: zero-dynamic-range rows/tensors would otherwise
# produce inf scales (a correctly-classified sample can have an exactly
# zero gradient row). A row with range <= _EPS_RANGE is reproduced
# exactly by the quantizer (scale caps keep s * x finite).
_EPS_RANGE = 1e-20
_MAX_SCALE = 1e20

GRAD_QUANTIZERS = ("ptq", "psq", "bhq", "fp8", "bfp")
VARIANTS = ("exact", "qat") + GRAD_QUANTIZERS


@dataclass(frozen=True)
class QuantConfig:
    """Static quantization configuration baked into one AOT artifact.

    kind: one of VARIANTS — 'exact' (no quantization anywhere), 'qat'
      (forward quantized, full-precision backward), or a gradient
      quantizer name (forward quantized + bifurcated quantized backward,
      Eq. 6). The Q_b2 bitwidth is a *runtime* scalar, not part of this
      config.
    fwd_bits: deterministic forward quantization (Q_f, Q_theta) bitwidth.
    b1_bits: Q_b1 bitwidth (the 8-bit stochastic PTQ used for the weight
      gradient product in the bifurcation, Appendix E).
    """

    kind: str = "ptq"
    fwd_bits: int = 8
    b1_bits: int = 8

    @property
    def quantizes_grad(self) -> bool:
        return self.kind in GRAD_QUANTIZERS

    @property
    def quantizes_fwd(self) -> bool:
        return self.kind != "exact"


def nbins(bits):
    """B = 2^bits - 1 (traced-friendly: bits may be a runtime f32 scalar)."""
    return jnp.exp2(jnp.asarray(bits, jnp.float32)) - 1.0


# ---------------------------------------------------------------------------
# Deterministic forward quantizers (Q_f, Q_theta) — round-to-nearest PTQ.
# ---------------------------------------------------------------------------


def ptq_det(x, bins):
    """Per-tensor round-to-nearest quantize-dequantize (forward path).

    Keeps the tensor's natural leading dimension as kernel rows (a (1, K)
    reshape would serialize the interpret-mode grid along one huge axis);
    the per-tensor scale/zero are broadcast to the per-row lanes.
    """
    shape = x.shape
    x2 = x.reshape(shape[0], -1) if x.ndim >= 2 else x.reshape(1, -1)
    n = x2.shape[0]
    lo = jnp.min(x2)
    rng = jnp.maximum(jnp.max(x2) - lo, _EPS_RANGE)
    s = jnp.minimum(bins / rng, _MAX_SCALE)
    scale = jnp.full((n, 1), s, jnp.float32)
    zero = jnp.full((n, 1), lo, jnp.float32)
    _, deq = rn_quant(x2, scale, zero, bins)
    return deq.reshape(shape)


# ---------------------------------------------------------------------------
# Stochastic gradient quantizers Q_b.
# ---------------------------------------------------------------------------


def ptq_stoch(x, key, bins):
    """Per-tensor stochastic quantizer (§3.3) — the INT8-training baseline."""
    n, d = x.shape
    lo = jnp.min(x)
    rng = jnp.maximum(jnp.max(x) - lo, _EPS_RANGE)
    s = jnp.minimum(bins / rng, _MAX_SCALE)
    scale = jnp.full((n, 1), s, jnp.float32)
    zero = jnp.full((n, 1), lo, jnp.float32)
    u = jax.random.uniform(key, x.shape, jnp.float32)
    _, deq = sr_quant(x, scale, zero, u, bins)
    return deq


def psq(x, key, bins):
    """Per-sample quantizer (§4.1): s_i = B / R(x_i), z_i = min(x_i)."""
    lo = jnp.min(x, axis=1, keepdims=True)
    rng = jnp.maximum(jnp.max(x, axis=1, keepdims=True) - lo, _EPS_RANGE)
    scale = jnp.minimum(bins / rng, _MAX_SCALE)
    u = jax.random.uniform(key, x.shape, jnp.float32)
    _, deq = sr_quant(x, scale, lo, u, bins)
    return deq


def _bhq_group_candidates(n):
    """Static sweep set for the number of groups G (App. D.5 step 2).

    Includes G = N: all-singleton groups make Q = I and s1 = B/R(row) —
    exactly PSQ. Without this fallback BHQ is strictly worse than PSQ on
    *homogeneous* gradients (no outlier rows), where any grouping smears
    equal-magnitude rows together (variance ~ m^2 per group, Appendix
    D.4). With it, BHQ >= PSQ everywhere and wins when outliers exist."""
    cands = []
    g = 1
    while g <= max(n // 2, 1):
        cands.append(g)
        g *= 2
    if n not in cands:
        cands.append(n)
    return tuple(cands)


def bhq_groups(mags, n_rows, proxy="extended"):
    """Appendix-D.5 group construction on sorted row magnitudes.

    Args:
      mags: (N,) row magnitudes |row|_inf sorted in DESCENDING order.
      n_rows: static N.
      proxy: "paper" uses Appendix D.5's variance proxy
        sum_i M_i^2 / m_i with m_i ~ 1 + (N-G) M_i / sum_{j<G} M_j.
        "extended" (default) uses the full D.4 per-group bound
        sum_i (M_i^{2/3} m_i^{-1/3} + lam2^{2/3} m_i^{2/3})^3 with
        lam2 ~ 2 M_G (largest non-leader magnitude). The paper's proxy is
        the lam2 -> 0 limit of this; it is blind to a second outlier row
        that lands *inside* a group (it would pick G=1 for two equal
        outliers). The `exp ablate-bhq-proxy` experiment quantifies the
        difference; both are available here and in rust/src/quant/bhq.rs.

    Returns:
      (gid, n_groups): gid[i] = group id of sorted row i (leaders are rows
      0..G-1, gid[i] = i for i < G); n_groups = traced selected G.
    """
    idx = jnp.arange(n_rows)
    proxies = []
    cands = _bhq_group_candidates(n_rows)
    for g in cands:
        topmask = idx < g
        mtop = jnp.where(topmask, mags, 0.0)
        tot = jnp.maximum(jnp.sum(mtop), _EPS_RANGE)
        sizes = 1.0 + (n_rows - g) * mtop / tot
        if proxy == "paper":
            proxies.append(jnp.sum(jnp.where(topmask, mtop**2 / sizes, 0.0)))
        else:
            lam2 = 2.0 * (mags[g] if g < n_rows else 0.0)
            term = (
                jnp.maximum(mtop, _EPS_RANGE) ** (2 / 3) * sizes ** (-1 / 3)
                + lam2 ** (2 / 3) * sizes ** (2 / 3)
            ) ** 3
            proxies.append(jnp.sum(jnp.where(topmask, term, 0.0)))
    proxies = jnp.stack(proxies)
    best = jnp.argmin(proxies)
    n_groups = jnp.asarray(cands)[best]

    # Assign non-leader rows to groups by cumulative fractional group size.
    topmask = idx < n_groups
    mtop = jnp.where(topmask, mags, 0.0)
    tot = jnp.maximum(jnp.sum(mtop), _EPS_RANGE)
    extras = (n_rows - n_groups) * mtop / tot  # fractional extra rows/group
    bounds = jnp.cumsum(extras)  # bounds[G-1] == N - G
    pos = idx.astype(jnp.float32) - n_groups.astype(jnp.float32) + 0.5
    assigned = jnp.searchsorted(bounds, pos, side="left")
    assigned = jnp.minimum(assigned, n_groups - 1)
    gid = jnp.where(topmask, idx, assigned)
    return gid, n_groups


def _bhq_matrices(xs, gid, bins):
    """Build per-row scales and the block-Householder Q for sorted rows.

    Returns (srow (N,1), Q (N,N)). Q is symmetric orthogonal (Q = Q^T,
    Q^2 = I) because it is a direct sum of Householder reflections over
    disjoint row groups.
    """
    n = xs.shape[0]
    idx = jnp.arange(n)
    is_leader = gid == idx

    mags = jnp.max(jnp.abs(xs), axis=1)  # |row|_inf (sorted order)
    rowrange = jnp.max(xs, axis=1) - jnp.min(xs, axis=1)

    m_g = jax.ops.segment_sum(jnp.ones(n), gid, num_segments=n)
    m_g = jnp.maximum(m_g, 1.0)
    # lambda1_g = R(leader row of group g) = rowrange[g] (leader is row g).
    # Floored relative to the leader's magnitude: a near-constant row
    # (range ~ 0, values large) would otherwise blow up s1 and the f32
    # cancellation error of the reflection scales with s1 * |x| (mirrors
    # rust/src/quant/bhq.rs).
    lam1 = jnp.maximum(jnp.maximum(rowrange, 1e-3 * mags), _EPS_RANGE)
    # lambda2_g = 2 * max_{non-leader members} |row|_inf.
    lam2 = jax.ops.segment_max(
        jnp.where(is_leader, 0.0, mags), gid, num_segments=n
    )
    lam2 = jnp.maximum(2.0 * lam2, _EPS_RANGE)

    denom = lam1 ** (2 / 3) * m_g ** (-1 / 3) + lam2 ** (2 / 3) * m_g ** (2 / 3)
    denom = jnp.maximum(denom, _EPS_RANGE)
    s1 = jnp.minimum(bins * lam1 ** (-1 / 3) * m_g ** (1 / 6) / denom, _MAX_SCALE)
    s2 = jnp.minimum(bins * lam2 ** (-1 / 3) * m_g ** (1 / 6) / denom, _MAX_SCALE)
    srow = jnp.where(is_leader, s1[gid], s2[gid])[:, None]

    # n_g = 1_group / sqrt(m_g) - e_leader, stacked as columns of Nm.
    member = (gid[:, None] == idx[None, :]).astype(jnp.float32)  # (row, g)
    eye = jnp.eye(n, dtype=jnp.float32)
    nm = member / jnp.sqrt(m_g)[None, :] - eye
    nsq = jnp.sum(nm * nm, axis=0)
    # Only columns of *real* groups contribute a reflection: group g exists
    # iff sorted row g is its own leader. Empty-group columns otherwise
    # degenerate to -e_g and would overlap real groups' support, breaking
    # blockwise orthogonality. Singleton groups have n = 0 -> identity.
    valid = is_leader & (nsq > 1e-12)
    inv_nsq = jnp.where(valid, 2.0 / jnp.maximum(nsq, 1e-12), 0.0)
    q = eye - (nm * inv_nsq[None, :]) @ nm.T
    return srow, q


def bhq(x, key, bins):
    """Block Householder quantizer (§4.2, App. D.4–D.5).

    Pipeline: sort rows by |row|_inf desc -> build groups (D.5) -> rotate
    with blockwise Householder Q and scale rows -> per-row zero-point ->
    stochastic round (L1 kernel) -> inverse transform -> unsort.
    Every step except SR is deterministic given x, so unbiasedness holds.
    """
    n, _ = x.shape
    mags = jnp.max(jnp.abs(x), axis=1)
    order = jnp.argsort(-mags)
    inv_order = jnp.argsort(order)
    xs = x[order]

    gid, _ = bhq_groups(mags[order], n)
    srow, q = _bhq_matrices(xs, gid, bins)

    y = qmatmul(q, srow * xs)  # S X = Q diag(s) X  (two L1 GEMM passes)
    zy = jnp.min(y, axis=1, keepdims=True)
    ones = jnp.ones_like(srow)
    u = jax.random.uniform(key, y.shape, jnp.float32)
    _, yhat = sr_quant(y, ones, zy, u, bins)
    xhat_s = qmatmul(q, yhat) / srow  # S^{-1} = diag(s)^{-1} Q (Q^2 = I)
    return xhat_s[inv_order]


# -- Extension formats (Table 2 comparison) ---------------------------------


def fp8_sim(x, key, exp_bits=4, man_bits=3):
    """FP8 (default E4M3) stochastic-rounding simulation, per-tensor scaled.

    The tensor is scaled so its absmax hits the format's max normal, then
    each element is stochastically rounded to the nearest representable
    FP8 grid point (step = 2^(floor(log2|x|) - man_bits), subnormals get
    the fixed step 2^(emin - man_bits)). Unbiased within range; values at
    the top of the range saturate (same convention as HFP8 hardware).
    """
    bias = 2 ** (exp_bits - 1) - 1
    emax = 2**exp_bits - 2 - bias  # reserve top exponent (E4M3 style)
    emin = 1 - bias
    max_normal = 2.0**emax * (2.0 - 2.0**-man_bits)

    absmax = jnp.maximum(jnp.max(jnp.abs(x)), _EPS_RANGE)
    s = max_normal / absmax
    xs = x * s

    ax = jnp.abs(xs)
    e = jnp.floor(jnp.log2(jnp.maximum(ax, 2.0**emin * 2.0**-man_bits)))
    e = jnp.clip(e, emin, emax)
    step = jnp.exp2(e - man_bits)
    u = jax.random.uniform(key, x.shape, jnp.float32)
    q = jnp.floor(xs / step + u) * step
    # Unbiasedness needs floor on the *signed* grid: floor handles both
    # signs correctly (grid is uniform within a binade).
    q = jnp.clip(q, -max_normal, max_normal)
    return q / s


def bfp(x, key, bins, block=64):
    """Block floating point (HBFP-style): shared exponent per block.

    Rows are split into length-`block` chunks along the feature axis; each
    chunk shares the exponent of its absmax and mantissas are
    stochastically rounded to log2(bins+1)-1 fractional bits equivalent —
    i.e. the chunk is affinely mapped to [-B/2, B/2] by a power-of-two
    scale. Power-of-two scales are what make BFP hardware-cheap.
    """
    n, d = x.shape
    pad = (-d) % block
    xp = jnp.pad(x, ((0, 0), (0, pad)))
    nb = (d + pad) // block
    xb = xp.reshape(n * nb, block)

    absmax = jnp.maximum(jnp.max(jnp.abs(xb), axis=1, keepdims=True), _EPS_RANGE)
    # power-of-two scale: largest 2^k with absmax * s <= bins/2
    s = jnp.exp2(jnp.floor(jnp.log2((bins / 2.0) / absmax)))
    s = jnp.minimum(s, _MAX_SCALE)
    u = jax.random.uniform(key, xb.shape, jnp.float32)
    q = jnp.floor(xb * s + u)
    deq = (q / s).reshape(n, d + pad)[:, :d]
    return deq


# ---------------------------------------------------------------------------
# Dispatch + sample-view plumbing.
# ---------------------------------------------------------------------------


def quantize_grad(kind, g, key, bins, sample_count=None):
    """Quantize an activation gradient with the named quantizer.

    `g` is (M, C). The paper's quantizers act on the (N, D) per-*sample*
    view of the gradient (N = batch samples); for convolutional layers M =
    N * positions, so we reshape to (N, M/N*C), quantize, and reshape back
    (DESIGN.md §2 "sample_rows"). PTQ is view-invariant; PSQ/BHQ are not.
    """
    m, c = g.shape
    n = sample_count or m
    view = g.reshape(n, (m // n) * c)
    if kind == "ptq":
        out = ptq_stoch(view, key, bins)
    elif kind == "psq":
        out = psq(view, key, bins)
    elif kind == "bhq":
        out = bhq(view, key, bins)
    elif kind == "fp8":
        out = fp8_sim(view, key)
    elif kind == "bfp":
        out = bfp(view, key, bins)
    else:
        raise ValueError(f"unknown gradient quantizer {kind!r}")
    return out.reshape(m, c)


# ---------------------------------------------------------------------------
# Theoretical variance bounds (used by tests and the Fig-3 analysis).
# ---------------------------------------------------------------------------


def ptq_variance_bound(x, bins):
    """Eq. (9): Var[Q_ptq(X)|X] <= N D / (4 B^2) * R(X)^2."""
    n, d = x.shape
    r = jnp.max(x) - jnp.min(x)
    return n * d / (4.0 * bins**2) * r**2


def psq_variance_bound(x, bins):
    """§4.1: Var[Q_psq(X)|X] <= D / (4 B^2) * sum_i R(x_i)^2."""
    d = x.shape[1]
    r = jnp.max(x, axis=1) - jnp.min(x, axis=1)
    return d / (4.0 * bins**2) * jnp.sum(r**2)


def sr_exact_variance(t):
    """Exact SR variance of an already-scaled tensor: sum p(1-p)."""
    p = t - jnp.floor(t)
    return jnp.sum(p * (1.0 - p))
