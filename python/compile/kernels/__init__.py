# L1: Pallas kernels (interpret=True) + pure-jnp reference oracles.
from . import ref  # noqa: F401
from .qmatmul import qmatmul, qmatmul_nt, qmatmul_tn  # noqa: F401
from .sr_quant import rn_quant, sr_quant  # noqa: F401
