# Pure-jnp correctness oracles for the L1 Pallas kernels.
#
# Every Pallas kernel in this package has an exact reference here; pytest
# (python/tests/) asserts allclose between the kernel (interpret=True) and
# these functions over hypothesis-driven shape/dtype sweeps. These oracles
# are the CORE correctness signal for the L1 layer.
import jax.numpy as jnp


def sr_quant_ref(y, scale, zero, noise, nbins):
    """Fused affine stochastic-round quantize/dequantize (row-wise params).

    Given an input matrix ``y`` (already rotated for BHQ; raw gradient for
    PTQ/PSQ), per-row ``scale`` and ``zero`` (shape (N, 1)), uniform noise
    ``u ~ U[0,1)`` of the same shape as ``y``, and the number of bins
    ``nbins`` (= 2^bits - 1, may be a traced scalar):

        t    = scale * (y - zero)            # map into [0, nbins]
        q    = clip(floor(t + u), 0, nbins)  # stochastic rounding
        yhat = q / scale + zero              # dequantize

    Returns ``(q, yhat)``. Stochastic rounding floor(t+u) is unbiased:
    E[floor(t + u)] = t for u ~ U[0,1) whenever 0 <= t <= nbins.
    """
    t = scale * (y - zero)
    q = jnp.clip(jnp.floor(t + noise), 0.0, nbins)
    yhat = q / scale + zero
    return q, yhat


def rn_quant_ref(y, scale, zero, nbins):
    """Deterministic round-to-nearest quantize/dequantize (forward path).

    Used for Q_f (activations) and Q_theta (weights) in QAT/FQT forward
    propagation, which the framework requires to be deterministic.
    """
    t = scale * (y - zero)
    q = jnp.clip(jnp.round(t), 0.0, nbins)
    yhat = q / scale + zero
    return q, yhat


def matmul_ref(a, b):
    """Plain f32 matmul oracle for the blocked Pallas qmatmul kernel."""
    return jnp.matmul(a, b, preferred_element_type=jnp.float32)


def rowstats_ref(x):
    """Per-row (min, max) reduction oracle.

    Returns (rmin, rmax) each of shape (N, 1). R(row) = rmax - rmin is the
    dynamic range that sets the PSQ scale s_i = B / R(row_i).
    """
    rmin = jnp.min(x, axis=1, keepdims=True)
    rmax = jnp.max(x, axis=1, keepdims=True)
    return rmin, rmax
