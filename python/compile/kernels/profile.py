# Kernel scheduling profiles.
#
# The BlockSpec tile sizes mean different things on the two execution
# paths:
#
#   * "tpu"  — the real-hardware schedule: 128-multiple tiles sized to the
#     MXU (128x128 systolic array) and the ~16 MiB/core VMEM budget
#     ((bm*bk + bk*bn + bm*bn) * 4B per grid step). This is what DESIGN.md
#     §9 estimates perf from.
#   * "cpu"  — the interpret-mode schedule used for the AOT artifacts in
#     this repo: interpret=True emulates each grid step with full-array
#     dynamic slices, so emulation overhead is proportional to grid size
#     and the fastest schedule is the *largest* tile that still divides
#     the dims (one grid step when possible). Numerics are identical
#     across profiles (test_kernels.py::test_block_shape_invariance).
#
# Select with STATQUANT_KERNEL_PROFILE=tpu|cpu (default cpu) at AOT time.
import os

PROFILES = {
    "tpu": {
        "mm_bm": 256,
        "mm_bk": 256,
        "mm_bn": 256,
        "sr_rows": 512,
        "sr_cols": 512,
    },
    "cpu": {
        "mm_bm": 1 << 16,
        "mm_bk": 4096,
        "mm_bn": 4096,
        "sr_rows": 1 << 16,
        "sr_cols": 1 << 16,
    },
}

_active = os.environ.get("STATQUANT_KERNEL_PROFILE", "cpu")


def set_profile(name):
    global _active
    if name not in PROFILES:
        raise ValueError(f"unknown kernel profile {name!r}")
    _active = name


def active():
    return _active


def get(key):
    return PROFILES[_active][key]
