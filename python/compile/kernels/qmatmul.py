# L1 Pallas kernel: blocked matmul — the low-bitwidth GEMM stand-in.
#
# In real INT8 FQT hardware this is the tensor-core / MXU integer GEMM over
# quantized operands. We follow the paper's own methodology (Appendix E:
# "we simulate the training with FP32"): operands are quantized values
# stored in f32, so the statistics of training are bit-exact with an INT
# pipeline while remaining executable on the CPU PJRT backend.
#
# TPU adaptation (DESIGN.md §3): classic MXU tiling. The (bm, bk) x
# (bk, bn) blocks are staged HBM->VMEM by BlockSpec; the k-dimension is the
# innermost grid axis so the f32 accumulator tile stays resident in VMEM
# across the contraction (revisiting semantics of the output BlockSpec).
# Block default 128 matches the 128x128 MXU systolic array. On CUDA the
# paper's kernels would express this with threadblock tiles + shared
# memory; BlockSpec is the TPU-side equivalent of that schedule.
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import profile


def _matmul_kernel(a_ref, b_ref, o_ref):
    """Grid (i, j, k): o[i,j] += a[i,k] @ b[k,j], accumulate over k."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def _pick(dim, ideal):
    """Largest divisor of `dim` that is <= `ideal`.

    Interpret-mode pallas fills out-of-bounds reads of ragged edge blocks
    with NaN (by design, to surface masking bugs), and a matmul
    accumulation would propagate them — so blocks must tile exactly. If
    only tiny divisors exist (prime-ish dims), fall back to one full
    block: on the interpret path a single grid step is also the fastest
    schedule, and on real TPU these shapes are padded upstream.
    """
    if dim <= ideal:
        return dim
    best = 1
    for d in range(ideal, 0, -1):
        if dim % d == 0:
            best = d
            break
    if best < max(ideal // 4, 1):
        return dim
    return best


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def _qmatmul(a, b, *, bm, bk, bn):
    """Blocked matmul a @ b over quantized-value operands.

    VMEM footprint per grid step is (bm*bk + bk*bn + bm*bn) * 4 bytes;
    defaults keep it well under the 16 MiB/core budget while the k-inner
    grid order preserves accumulator locality (see DESIGN.md §9).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction mismatch {a.shape} @ {b.shape}"
    bm_, bk_, bn_ = _pick(m, bm), _pick(k, bk), _pick(n, bn)
    grid = (pl.cdiv(m, bm_), pl.cdiv(n, bn_), pl.cdiv(k, bk_))
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk_, bn_), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)


def qmatmul(a, b, *, bm=None, bk=None, bn=None):
    """Profile-aware entry point: tile sizes default to the active kernel
    profile (see kernels/profile.py — TPU-shaped vs interpret-optimal)."""
    return _qmatmul(
        a,
        b,
        bm=bm or profile.get("mm_bm"),
        bk=bk or profile.get("mm_bk"),
        bn=bn or profile.get("mm_bn"),
    )


def qmatmul_tn(a, b, **kw):
    """a.T @ b — the weight-gradient product H~^T @ Q_b1(grad)."""
    return qmatmul(a.T, b, **kw)


def qmatmul_nt(a, b, **kw):
    """a @ b.T — the activation-gradient product Q_b2(grad) @ W~^T."""
    return qmatmul(a, b.T, **kw)
