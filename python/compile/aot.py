# AOT pipeline: lower every (model, variant, step) to HLO *text* +
# a JSON metadata sidecar + the initial parameter vector.
#
# HLO text (NOT lowered.compile() / .serialize()): jax >= 0.5 emits
# HloModuleProtos with 64-bit instruction ids which the xla crate's
# xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the HLO text
# parser reassigns ids, so text round-trips cleanly (see
# /opt/xla-example/README.md).
#
# Outputs under artifacts/:
#   <model>_<variant>_<step>.hlo.txt   HLO text, loadable by rust runtime/
#   <model>_<variant>_<step>.json      ABI metadata (shapes, dtypes)
#   <model>_init.bin                   f32-LE initial flat parameters
#   manifest.json                      index of everything built
import argparse
import json
import os
import sys
import time

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from . import quantizers as Q

# Which artifacts exist: gradient-quantizer variants get train+probe;
# exact/qat get train+probe (QAT probe = the Var[QAT grad] baseline of
# Fig 3); eval/actgrad are variant-independent (eval uses the qat forward
# = the quantized model Eq. 3; actgrad uses the qat backward).
GRAD_VARIANTS = ("ptq", "psq", "bhq")
EXT_VARIANTS = ("fp8", "bfp")  # Table-2 formats: built for cnn only


def artifact_plan(model_name):
    plan = []
    variants = ("exact", "qat") + GRAD_VARIANTS
    if model_name == "cnn":
        variants = variants + EXT_VARIANTS
    for v in variants:
        plan.append((v, "train"))
        plan.append((v, "probe"))
    plan.append(("qat", "eval"))
    plan.append(("qat", "actgrad"))
    return plan


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_meta(s):
    return {"shape": list(s.shape), "dtype": str(np.dtype(s.dtype).name)}


def build_artifact(bm, kind, out_dir):
    name = f"{bm.name}_{bm.qcfg.kind}_{kind}"
    hlo_path = os.path.join(out_dir, f"{name}.hlo.txt")
    meta_path = os.path.join(out_dir, f"{name}.json")
    t0 = time.time()
    lowered, args = M.lower_step(bm, kind)
    text = to_hlo_text(lowered)
    with open(hlo_path, "w") as f:
        f.write(text)
    meta = {
        "model": bm.name,
        "variant": bm.qcfg.kind,
        "step": kind,
        "n_params": bm.n_params,
        "batch": bm.cfg.input_shape[0],
        "input_shape": list(bm.cfg.input_shape),
        "input_dtype": bm.cfg.input_dtype,
        "inputs": [_spec_meta(a) for a in args],
        "outputs": [_spec_meta(o) for o in jax.tree.leaves(lowered.out_info)],
        "probe_shape": list(bm.mod.probe_shape(bm.cfg)),
        "momentum": M.MOMENTUM,
        "hlo_bytes": len(text),
        "lower_seconds": round(time.time() - t0, 2),
    }
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=1)
    return name, meta


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts dir")
    ap.add_argument(
        "--models",
        default="mlp,cnn,resnet,transformer",
        help="comma-separated subset of models to build",
    )
    ap.add_argument("--seed", type=int, default=0, help="init seed")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"models": {}, "artifacts": []}
    for model_name in args.models.split(","):
        model_name = model_name.strip()
        if model_name not in M.MODELS:
            sys.exit(f"unknown model {model_name!r}")
        built_any = None
        for variant, kind in artifact_plan(model_name):
            bm = M.build(model_name, variant, seed=args.seed)
            built_any = bm
            name, meta = build_artifact(bm, kind, args.out)
            manifest["artifacts"].append(name)
            print(
                f"[aot] {name}: P={meta['n_params']} "
                f"hlo={meta['hlo_bytes']//1024}KiB "
                f"({meta['lower_seconds']}s)",
                flush=True,
            )
        init_path = os.path.join(args.out, f"{model_name}_init.bin")
        built_any.params0_flat.astype("<f4").tofile(init_path)
        manifest["models"][model_name] = {
            "n_params": built_any.n_params,
            "init": os.path.basename(init_path),
        }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {len(manifest['artifacts'])} artifacts to {args.out}")


if __name__ == "__main__":
    main()
