# L2: quantized layers with the paper's FQT backward pass (Eq. 4-6).
#
# `qlinear` is the single quantized compute primitive every model routes
# through (fully-connected directly; convolutions via im2col). It is a
# jax.custom_vjp whose
#
#   forward  (Eq. 3):  out = Q_f(H) @ Q_theta(W)          [deterministic]
#   backward (Eq. 6), with gradient bifurcation [Banner et al. '18]:
#       grad_W = Q_f(H)^T @ Q_b1(g)      Q_b1 = 8-bit stochastic PTQ
#       grad_H = Q_b2(g)  @ Q_theta(W)^T Q_b2 = PTQ/PSQ/BHQ @ runtime bits
#
# The straight-through estimator is implicit: grad_H flows as if Q_f were
# the identity, exactly Eq. (4)'s convention.
#
# Randomness: each training step carries one f32 `seed` scalar across the
# Rust<->HLO ABI; every layer folds in its static layer_id (and a b1/b2
# lane) so all quantizers draw independent streams. custom_vjp returns a
# zero cotangent for `seed` and `bits`.
import jax
import jax.numpy as jnp

from . import quantizers as Q
from .kernels import qmatmul

# Toggle to route GEMMs through the L1 Pallas kernel (default) or plain
# jnp (used to isolate kernel overhead in the perf pass; artifacts always
# ship the kernel path unless aot.py is told otherwise).
USE_PALLAS_GEMM = True


def _mm(a, b):
    if USE_PALLAS_GEMM:
        return qmatmul(a, b)
    return jnp.matmul(a, b, preferred_element_type=jnp.float32)


def _seed_key(seed, layer_id, lane):
    """Derive an independent PRNG stream from the ABI seed scalar."""
    base = jax.random.PRNGKey(jnp.asarray(seed, jnp.float32).astype(jnp.uint32))
    return jax.random.fold_in(jax.random.fold_in(base, layer_id), lane)


def make_qlinear(layer_id, qcfg: Q.QuantConfig, sample_count=None,
                 h_prequantized=False):
    """Build the quantized linear primitive for one layer.

    Args:
      layer_id: static int, unique per qlinear call site in the model.
      qcfg: static QuantConfig (variant + forward bitwidths).
      sample_count: static batch size N for the per-sample gradient view
        (None = rows are samples; conv layers pass N explicitly).
      h_prequantized: the caller already applied Q_f to `h` (conv layers
        quantize the activation *before* im2col, so the 9x-duplicated
        patch matrix is not re-quantized — identical values, 9x less
        work; see DESIGN.md §Perf).

    Returns:
      qlinear(h, w, seed, bits) -> h @ w with the FQT backward.
    """
    fwd_bins = float(2**qcfg.fwd_bits - 1)
    b1_bins = float(2**qcfg.b1_bits - 1)

    @jax.custom_vjp
    def qlinear(h, w, seed, bits):
        out, _ = _fwd(h, w, seed, bits)
        return out

    def _fwd(h, w, seed, bits):
        if qcfg.quantizes_fwd:
            ht = h if h_prequantized else Q.ptq_det(h, fwd_bins)
            wt = Q.ptq_det(w, fwd_bins)
        else:
            ht, wt = h, w
        out = _mm(ht, wt)
        return out, (ht, wt, seed, bits)

    def _bwd(res, g):
        ht, wt, seed, bits = res
        if qcfg.quantizes_grad:
            bins = Q.nbins(bits)
            g1 = Q.ptq_stoch(g, _seed_key(seed, layer_id, 1), b1_bins)
            g2 = Q.quantize_grad(
                qcfg.kind, g, _seed_key(seed, layer_id, 2), bins, sample_count
            )
        else:  # exact / QAT: full-precision backward
            g1 = g2 = g
        dw = _mm(ht.T, g1)
        dh = _mm(g2, wt.T)
        return dh, dw, jnp.zeros(()), jnp.zeros(())

    qlinear.defvjp(_fwd, _bwd)
    return qlinear


def ste_quantize(x, bins):
    """Straight-through Q_f: forward = deterministic per-tensor
    round-to-nearest, backward = identity (Eq. 4's STE convention).
    Used by conv layers to quantize the activation before im2col."""

    @jax.custom_vjp
    def q(x):
        return Q.ptq_det(x, bins)

    q.defvjp(lambda x: (Q.ptq_det(x, bins), None), lambda _, g: (g,))
    return q(x)


def make_qidentity(layer_id, qcfg: Q.QuantConfig, sample_count=None):
    """Quantization tap for non-GEMM layers (paper: "we quantize the inputs
    and gradients of batch normalization layers").

    Forward: deterministic Q_f (STE). Backward: Q_b2 on the incoming
    gradient. A no-op for exact; forward-only for QAT.
    """
    fwd_bins = float(2**qcfg.fwd_bits - 1)

    @jax.custom_vjp
    def qid(x, seed, bits):
        return Q.ptq_det(x, fwd_bins) if qcfg.quantizes_fwd else x

    def _fwd(x, seed, bits):
        return qid(x, seed, bits), (x.shape, seed, bits)

    def _bwd(res, g):
        shape, seed, bits = res
        if qcfg.quantizes_grad:
            g2 = g.reshape(shape[0], -1)
            g2 = Q.quantize_grad(
                qcfg.kind,
                g2,
                _seed_key(seed, layer_id, 3),
                Q.nbins(bits),
                sample_count,
            )
            g = g2.reshape(shape)
        return g, jnp.zeros(()), jnp.zeros(())

    qid.defvjp(_fwd, _bwd)
    return qid


class LayerIds:
    """Monotone layer-id allocator so every quantized call site in a model
    gets a distinct PRNG stream."""

    def __init__(self):
        self._next = 0

    def fresh(self):
        i = self._next
        self._next += 1
        return i
