# AOT pipeline: the HLO-text interchange contract the Rust runtime
# depends on. Lowers the smallest artifact in-process and validates the
# text, metadata, and parameter pruning behaviour (keep_unused).
import json
import os

import jax
import numpy as np
import pytest

from compile import aot, model as M

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def lowered_mlp_exact():
    bm = M.build("mlp", "exact")
    lowered, args = M.lower_step(bm, "train")
    return bm, lowered, args


class TestLowering:
    def test_hlo_text_parses_as_hlo(self, lowered_mlp_exact):
        _, lowered, _ = lowered_mlp_exact
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule")
        assert "ENTRY" in text

    def test_all_abi_params_survive_lowering(self, lowered_mlp_exact):
        """keep_unused contract: exact ignores seed/bits but the HLO must
        still declare all 7 parameters (regression for the 7-vs-5 buffer
        mismatch the Rust runtime hit)."""
        _, lowered, args = lowered_mlp_exact
        text = aot.to_hlo_text(lowered)
        entry = [l for l in text.splitlines() if "ENTRY" in l][0]
        n_params = entry.count("parameter") or entry.count("f32[")
        # count parameter declarations in the entry computation body
        body_params = [
            l for l in text.splitlines() if "= parameter(" in l or " parameter(" in l
        ]
        assert len(body_params) >= len(args), (len(body_params), len(args))

    def test_artifact_plan_contents(self):
        plan = aot.artifact_plan("mlp")
        variants = {v for v, _ in plan}
        steps = {s for _, s in plan}
        assert {"exact", "qat", "ptq", "psq", "bhq"} <= variants
        assert steps == {"train", "probe", "eval", "actgrad"}
        # extension formats only for cnn
        assert "fp8" not in variants
        assert {"fp8", "bfp"} <= {v for v, _ in aot.artifact_plan("cnn")}

    def test_spec_meta_shapes(self):
        s = jax.ShapeDtypeStruct((3, 4), np.float32)
        m = aot._spec_meta(s)
        assert m == {"shape": [3, 4], "dtype": "float32"}


class TestArtifactsOnDisk:
    """Validate the artifacts directory if `make artifacts` has run."""

    @pytest.fixture(scope="class")
    def adir(self):
        d = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
        if not os.path.exists(os.path.join(d, "manifest.json")):
            pytest.skip("artifacts not built")
        return d

    def test_manifest_lists_existing_files(self, adir):
        with open(os.path.join(adir, "manifest.json")) as f:
            manifest = json.load(f)
        for name in manifest["artifacts"]:
            assert os.path.exists(os.path.join(adir, f"{name}.hlo.txt")), name
            assert os.path.exists(os.path.join(adir, f"{name}.json")), name
        for model, info in manifest["models"].items():
            init = os.path.join(adir, info["init"])
            assert os.path.getsize(init) == 4 * info["n_params"]

    def test_sidecar_abi_consistency(self, adir):
        with open(os.path.join(adir, "mlp_ptq_train.json")) as f:
            meta = json.load(f)
        assert meta["model"] == "mlp"
        assert len(meta["inputs"]) == 7  # p, m, x, y, seed, lr, bits
        assert len(meta["outputs"]) == 4  # p', m', loss, acc
        assert meta["inputs"][0]["shape"] == [meta["n_params"]]
        assert meta["inputs"][4]["shape"] == []  # seed scalar

    def test_probe_abi(self, adir):
        with open(os.path.join(adir, "mlp_bhq_probe.json")) as f:
            meta = json.load(f)
        assert len(meta["inputs"]) == 5
        assert meta["outputs"][1]["shape"] == [meta["n_params"]]

    def test_init_params_finite_and_scaled(self, adir):
        p = np.fromfile(os.path.join(adir, "mlp_init.bin"), dtype="<f4")
        assert np.isfinite(p).all()
        assert 0.01 < np.abs(p).max() < 10.0
