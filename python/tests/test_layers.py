# FQT backward correctness: the custom_vjp qlinear must implement
# Eq. (4) (QAT) and Eq. (6) (FQT with bifurcation) exactly, and Theorem 1
# (E[FQT grad | batch] = QAT grad) must hold statistically end to end.
import jax
import jax.numpy as jnp
import numpy as np

from compile import quantizers as Q
from compile.layers import LayerIds, make_qidentity, make_qlinear

jax.config.update("jax_platform_name", "cpu")


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


class TestExactVariant:
    def test_forward_is_plain_matmul(self):
        qlin = make_qlinear(0, Q.QuantConfig(kind="exact"))
        h, w = rand(0, 8, 16), rand(1, 16, 4)
        np.testing.assert_allclose(
            qlin(h, w, 0.0, 8.0), h @ w, rtol=1e-4, atol=1e-5
        )

    def test_gradients_match_autodiff(self):
        qlin = make_qlinear(0, Q.QuantConfig(kind="exact"))
        h, w = rand(2, 6, 10), rand(3, 10, 3)

        def f_q(h, w):
            return jnp.sum(jnp.sin(qlin(h, w, 0.0, 8.0)))

        def f_ref(h, w):
            return jnp.sum(jnp.sin(h @ w))

        gq = jax.grad(f_q, argnums=(0, 1))(h, w)
        gr = jax.grad(f_ref, argnums=(0, 1))(h, w)
        for a, b in zip(gq, gr):
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


class TestQATVariant:
    def test_forward_quantized_backward_ste(self):
        """QAT: out = Q(h) @ Q(w); grads = g @ Q(w)^T, Q(h)^T @ g (STE)."""
        qcfg = Q.QuantConfig(kind="qat")
        qlin = make_qlinear(0, qcfg)
        h, w = rand(4, 5, 8), rand(5, 8, 3)
        ht = Q.ptq_det(h, 255.0)
        wt = Q.ptq_det(w, 255.0)
        out = qlin(h, w, 0.0, 8.0)
        np.testing.assert_allclose(out, ht @ wt, rtol=1e-4, atol=1e-5)

        g = rand(6, 5, 3)
        dh, dw = jax.vjp(lambda h, w: qlin(h, w, 0.0, 8.0), h, w)[1](g)[:2]
        np.testing.assert_allclose(dh, g @ wt.T, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(dw, ht.T @ g, rtol=1e-4, atol=1e-5)


class TestFQTVariant:
    def test_backward_matches_eq6_with_same_keys(self):
        """Reconstruct Eq. (6) by hand with the layer's PRNG convention and
        compare bit-for-bit with the custom_vjp backward."""
        layer_id = 7
        qcfg = Q.QuantConfig(kind="psq")
        qlin = make_qlinear(layer_id, qcfg)
        h, w = rand(7, 6, 12), rand(8, 12, 4)
        g = rand(9, 6, 4)
        seed, bits = 42.0, 5.0

        _, vjp = jax.vjp(lambda h, w: qlin(h, w, seed, bits), h, w)
        dh, dw = vjp(g)[:2]

        # hand-rolled Eq. (6)
        base = jax.random.PRNGKey(jnp.asarray(seed).astype(jnp.uint32))
        kl = jax.random.fold_in(base, layer_id)
        k1 = jax.random.fold_in(kl, 1)
        k2 = jax.random.fold_in(kl, 2)
        ht, wt = Q.ptq_det(h, 255.0), Q.ptq_det(w, 255.0)
        g1 = Q.ptq_stoch(g, k1, 255.0)
        g2 = Q.psq(g, k2, Q.nbins(bits))
        np.testing.assert_allclose(dw, ht.T @ g1, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(dh, g2 @ wt.T, rtol=1e-4, atol=1e-5)

    def test_seed_and_bits_get_zero_cotangent(self):
        qlin = make_qlinear(0, Q.QuantConfig(kind="ptq"))
        h, w = rand(10, 4, 6), rand(11, 6, 2)

        def f(h, w, seed, bits):
            return jnp.sum(qlin(h, w, seed, bits))

        ds, db = jax.grad(f, argnums=(2, 3))(h, w, 1.0, 5.0)
        assert float(ds) == 0.0 and float(db) == 0.0

    def test_different_seeds_different_noise(self):
        qlin = make_qlinear(0, Q.QuantConfig(kind="ptq"))
        h, w = rand(12, 4, 6), rand(13, 6, 2)
        g = rand(14, 4, 2)

        def bwd(seed):
            _, vjp = jax.vjp(lambda h, w: qlin(h, w, seed, 3.0), h, w)
            return vjp(g)[0]

        assert not np.allclose(bwd(1.0), bwd(2.0))
        np.testing.assert_array_equal(np.asarray(bwd(5.0)), np.asarray(bwd(5.0)))

    def test_theorem1_unbiased_through_two_layers(self):
        """E[FQT grad | batch] == QAT grad through a stacked network —
        the end-to-end statement of Theorem 1 (statistical)."""
        qcfg_fqt = Q.QuantConfig(kind="ptq")
        qcfg_qat = Q.QuantConfig(kind="qat")
        w1, w2 = rand(15, 8, 16), rand(16, 16, 4)
        x = rand(17, 12, 8)
        y = jax.nn.one_hot(jnp.arange(12) % 4, 4)

        def loss(variant_cfg, seed):
            l1 = make_qlinear(0, variant_cfg)
            l2 = make_qlinear(1, variant_cfg)

            def f(w1, w2):
                h = jnp.maximum(l1(x, w1, seed, 4.0), 0.0)
                o = l2(h, w2, seed, 4.0)
                return -jnp.mean(jnp.sum(jax.nn.log_softmax(o) * y, -1))

            return jax.grad(f, argnums=(0, 1))(w1, w2)

        g_qat = loss(qcfg_qat, 0.0)
        reps = 300
        acc = [jnp.zeros_like(w1), jnp.zeros_like(w2)]
        f_fqt = jax.jit(lambda s: loss(qcfg_fqt, s))
        for i in range(reps):
            g = f_fqt(float(i) + 1.0)
            acc = [a + gi for a, gi in zip(acc, g)]
        for a, gq in zip(acc, g_qat):
            mean = a / reps
            # normalize by gradient scale
            denom = float(jnp.abs(gq).max()) + 1e-8
            rel = float(jnp.abs(mean - gq).max()) / denom
            assert rel < 0.25, rel


class TestQIdentity:
    def test_forward_quantizes_backward_quantizes(self):
        qcfg = Q.QuantConfig(kind="ptq")
        qid = make_qidentity(3, qcfg, sample_count=4)
        x = rand(18, 4, 6)
        out = qid(x, 0.0, 8.0)
        np.testing.assert_allclose(out, Q.ptq_det(x, 255.0), atol=1e-6)

        g = rand(19, 4, 6)
        _, vjp = jax.vjp(lambda x: qid(x, 7.0, 4.0), x)
        (dx,) = vjp(g)
        assert dx.shape == x.shape
        # quantized: values differ from g but are close at 4 bits scale
        assert not np.allclose(np.asarray(dx), np.asarray(g))

    def test_exact_is_noop(self):
        qid = make_qidentity(0, Q.QuantConfig(kind="exact"))
        x = rand(20, 3, 5)
        np.testing.assert_array_equal(np.asarray(qid(x, 0.0, 8.0)), np.asarray(x))

    def test_layer_ids_monotone(self):
        ids = LayerIds()
        assert [ids.fresh() for _ in range(4)] == [0, 1, 2, 3]
