# Model-zoo correctness: shapes, finiteness, variant equivalences, and
# learning on the smallest configurations (kept fast — the heavy
# end-to-end checks live in rust/tests/integration.rs over the artifacts).
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import quantizers as Q
from compile.models import cnn, mlp, transformer
from compile.models.common import batchnorm, cross_entropy, im2col, layernorm

jax.config.update("jax_platform_name", "cpu")


class TestCommon:
    def test_cross_entropy_uniform(self):
        logits = jnp.zeros((4, 10))
        y = jnp.asarray([0, 3, 5, 9])
        loss, acc = cross_entropy(logits, y)
        assert abs(float(loss) - np.log(10)) < 1e-5
        assert 0.0 <= float(acc) <= 1.0

    def test_cross_entropy_perfect(self):
        y = jnp.asarray([0, 1])
        logits = jax.nn.one_hot(y, 3) * 100.0
        loss, acc = cross_entropy(logits, y)
        assert float(loss) < 1e-3
        assert float(acc) == 1.0

    def test_im2col_matches_conv(self):
        """im2col + GEMM == lax.conv for a random case."""
        k = jax.random.PRNGKey(0)
        x = jax.random.normal(k, (2, 8, 8, 3))
        w = jax.random.normal(jax.random.fold_in(k, 1), (3, 3, 3, 5))
        patches, (oh, ow) = im2col(x, 3, 3, 1, 1)
        # weight layout: rows iterate (i, j, c) in the same order as im2col
        wmat = w.reshape(9 * 3, 5)
        got = (patches @ wmat).reshape(2, oh, ow, 5)
        want = jax.lax.conv_general_dilated(
            x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
        )
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)

    def test_im2col_stride_shapes(self):
        x = jnp.zeros((4, 16, 16, 8))
        p, (oh, ow) = im2col(x, 3, 3, 2, 1)
        assert (oh, ow) == (8, 8)
        assert p.shape == (4 * 64, 9 * 8)

    def test_batchnorm_normalizes(self):
        k = jax.random.PRNGKey(2)
        x = jax.random.normal(k, (8, 4, 4, 3)) * 5 + 2
        params = {"gamma": jnp.ones((3,)), "beta": jnp.zeros((3,))}
        y = batchnorm(params, x)
        assert float(jnp.abs(jnp.mean(y, axis=(0, 1, 2))).max()) < 1e-4
        assert float(jnp.abs(jnp.var(y, axis=(0, 1, 2)) - 1.0).max()) < 1e-2

    def test_layernorm_shape_and_stats(self):
        x = jax.random.normal(jax.random.PRNGKey(3), (2, 5, 8))
        params = {"gamma": jnp.ones((8,)), "beta": jnp.zeros((8,))}
        y = layernorm(params, x)
        assert y.shape == x.shape
        assert float(jnp.abs(jnp.mean(y, -1)).max()) < 1e-4


class TestZoo:
    @pytest.mark.parametrize("name", ["mlp", "cnn", "transformer"])
    def test_logits_shape_and_finite(self, name):
        bm = M.build(name, "qat")
        rng = np.random.default_rng(0)
        if bm.cfg.input_dtype == "f32":
            x = jnp.asarray(rng.normal(size=bm.cfg.input_shape), jnp.float32)
        else:
            x = jnp.asarray(rng.integers(0, 256, bm.cfg.input_shape), jnp.int32)
        params = bm.unravel(jnp.asarray(bm.params0_flat))
        logits = bm.mod.apply(params, x, 0.0, 8.0, bm.qcfg, bm.cfg)
        if name == "transformer":
            assert logits.shape == (*bm.cfg.input_shape, bm.cfg.vocab)
        else:
            assert logits.shape == (bm.cfg.input_shape[0], 10)
        assert bool(jnp.isfinite(logits).all())

    def test_param_counts_stable(self):
        """Flat-vector ABI contract: param count is deterministic."""
        assert M.build("mlp", "ptq").n_params == M.build("mlp", "bhq").n_params
        assert M.build("mlp", "ptq").n_params == 26122

    def test_variants_share_init(self):
        a = M.build("cnn", "ptq", seed=3)
        b = M.build("cnn", "bhq", seed=3)
        np.testing.assert_array_equal(a.params0_flat, b.params0_flat)
        c = M.build("cnn", "ptq", seed=4)
        assert not np.array_equal(a.params0_flat, c.params0_flat)

    def test_probe_shapes_consistent(self):
        for name in ["mlp", "cnn", "transformer"]:
            bm = M.build(name, "qat")
            shape = bm.mod.probe_shape(bm.cfg)
            assert shape[0] == bm.cfg.input_shape[0]
            assert np.prod(shape) > 0


class TestTrainStep:
    def test_mlp_learns_fast(self):
        """30 FQT steps on separable data must drop the loss sharply."""
        bm = M.build("mlp", "psq")
        step = jax.jit(M.make_train_step(bm))
        rng = np.random.default_rng(0)
        # two separable gaussian blobs over 10 classes
        protos = rng.normal(size=(10, 64)).astype(np.float32)
        y = rng.integers(0, 10, 64).astype(np.int32)
        x = (protos[y] + 0.3 * rng.normal(size=(64, 64))).astype(np.float32)
        p = jnp.asarray(bm.params0_flat)
        m = jnp.zeros_like(p)
        first = None
        for i in range(30):
            p, m, loss, _ = step(p, m, x, y, float(i), 0.1, 5.0)
            if first is None:
                first = float(loss)
        assert float(loss) < first * 0.3, (first, float(loss))

    def test_exact_train_step_deterministic(self):
        bm = M.build("mlp", "exact")
        step = jax.jit(M.make_train_step(bm), keep_unused=True)
        rng = np.random.default_rng(1)
        x = rng.normal(size=(64, 64)).astype(np.float32)
        y = rng.integers(0, 10, 64).astype(np.int32)
        p = jnp.asarray(bm.params0_flat)
        m = jnp.zeros_like(p)
        o1 = step(p, m, x, y, 1.0, 0.1, 5.0)
        o2 = step(p, m, x, y, 2.0, 0.1, 5.0)  # seed unused for exact
        np.testing.assert_array_equal(np.asarray(o1[0]), np.asarray(o2[0]))

    def test_probe_step_grad_matches_train_direction(self):
        """probe grad == the momentum delta of a zero-momentum train step."""
        bm = M.build("mlp", "qat")
        train = jax.jit(M.make_train_step(bm), keep_unused=True)
        probe = jax.jit(M.make_probe_step(bm), keep_unused=True)
        rng = np.random.default_rng(2)
        x = rng.normal(size=(64, 64)).astype(np.float32)
        y = rng.integers(0, 10, 64).astype(np.int32)
        p = jnp.asarray(bm.params0_flat)
        m = jnp.zeros_like(p)
        _, m1, _, _ = train(p, m, x, y, 0.0, 0.1, 8.0)
        _, g = probe(p, x, y, 0.0, 8.0)
        np.testing.assert_allclose(np.asarray(m1), np.asarray(g), rtol=1e-5, atol=1e-6)

    def test_actgrad_nonzero_and_shaped(self):
        bm = M.build("mlp", "qat")
        act = jax.jit(M.make_actgrad_step(bm), keep_unused=True)
        rng = np.random.default_rng(3)
        x = rng.normal(size=(64, 64)).astype(np.float32)
        y = rng.integers(0, 10, 64).astype(np.int32)
        g = act(jnp.asarray(bm.params0_flat), x, y, 0.0)
        assert g.shape == (64, 128)
        assert bool(jnp.any(g != 0))
