# L1 correctness: Pallas kernels (interpret=True) vs pure-jnp oracles.
#
# This is the CORE correctness signal for the kernel layer: hypothesis
# sweeps shapes/scales and asserts exact (or allclose) agreement between
# the fused kernels and ref.py. The same noise tensor feeds both sides, so
# the stochastic kernel is compared deterministically.
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import qmatmul, rn_quant, sr_quant
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

dims = st.integers(min_value=1, max_value=65)
small_dims = st.integers(min_value=1, max_value=17)


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


class TestSrQuant:
    @settings(max_examples=25, deadline=None)
    @given(n=dims, d=dims, seed=st.integers(0, 2**31 - 1))
    def test_matches_ref_exactly(self, n, d, seed):
        k = jax.random.PRNGKey(seed)
        y = jax.random.normal(k, (n, d)) * 3.0
        lo = jnp.min(y, axis=1, keepdims=True)
        rng = jnp.maximum(jnp.max(y, axis=1, keepdims=True) - lo, 1e-20)
        scale = 15.0 / rng
        u = jax.random.uniform(jax.random.fold_in(k, 1), (n, d))
        q_k, d_k = sr_quant(y, scale, lo, u, 15.0)
        q_r, d_r = ref.sr_quant_ref(y, scale, lo, u, 15.0)
        np.testing.assert_allclose(q_k, q_r, rtol=0, atol=0)
        np.testing.assert_allclose(d_k, d_r, rtol=1e-6, atol=1e-6)

    @settings(max_examples=10, deadline=None)
    @given(
        n=dims,
        d=dims,
        br=st.sampled_from([1, 2, 8, 64]),
        bc=st.sampled_from([1, 4, 32, 128]),
    )
    def test_block_shape_invariance(self, n, d, br, bc):
        """Any legal tiling produces identical results (scheduling must
        not change numerics)."""
        y = rand(0, n, d)
        scale = jnp.full((n, 1), 7.5, jnp.float32)
        zero = jnp.full((n, 1), -1.0, jnp.float32)
        u = jax.random.uniform(jax.random.PRNGKey(1), (n, d))
        qa, da = sr_quant(y, scale, zero, u, 255.0)
        qb, db = sr_quant(y, scale, zero, u, 255.0, block_rows=br, block_cols=bc)
        np.testing.assert_array_equal(np.asarray(qa), np.asarray(qb))
        np.testing.assert_array_equal(np.asarray(da), np.asarray(db))

    def test_codes_integer_in_range(self):
        y = rand(3, 32, 48) * 10
        lo = jnp.min(y, axis=1, keepdims=True)
        rng = jnp.maximum(jnp.max(y, axis=1, keepdims=True) - lo, 1e-20)
        scale = 31.0 / rng
        u = jax.random.uniform(jax.random.PRNGKey(5), y.shape)
        q, _ = sr_quant(y, scale, lo, u, 31.0)
        q = np.asarray(q)
        assert q.min() >= 0 and q.max() <= 31
        np.testing.assert_array_equal(q, np.floor(q))

    def test_traced_nbins_scalar(self):
        """bits is a runtime input in the artifacts: nbins must trace."""

        @jax.jit
        def f(y, u, nb):
            s = jnp.ones((y.shape[0], 1))
            z = jnp.zeros((y.shape[0], 1))
            return sr_quant(y, s, z, u, nb)

        y = jnp.abs(rand(7, 8, 8)) * 5
        u = jax.random.uniform(jax.random.PRNGKey(8), y.shape)
        q3, _ = f(y, u, 7.0)
        q8, _ = f(y, u, 255.0)
        assert np.asarray(q3).max() <= 7
        assert np.asarray(q8).max() <= 255

    def test_unbiased_statistically(self):
        y = rand(11, 4, 16) * 2
        lo = jnp.min(y, axis=1, keepdims=True)
        scale = 15.0 / jnp.maximum(jnp.max(y, axis=1, keepdims=True) - lo, 1e-20)
        reps = 800
        acc = jnp.zeros_like(y)
        for i in range(reps):
            u = jax.random.uniform(jax.random.PRNGKey(i), y.shape)
            _, d = sr_quant(y, scale, lo, u, 15.0)
            acc = acc + d
        err = jnp.abs(acc / reps - y).max()
        # bin size ~ R/15, SE of mean ~ bin/sqrt(12*reps) ~ 0.003*R
        assert err < 0.05, err


class TestRnQuant:
    @settings(max_examples=20, deadline=None)
    @given(n=dims, d=dims, seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, n, d, seed):
        y = jax.random.normal(jax.random.PRNGKey(seed), (n, d))
        scale = jnp.full((n, 1), 20.0, jnp.float32)
        zero = jnp.full((n, 1), -2.0, jnp.float32)
        q_k, d_k = rn_quant(y, scale, zero, 255.0)
        q_r, d_r = ref.rn_quant_ref(y, scale, zero, 255.0)
        np.testing.assert_allclose(q_k, q_r, atol=0)
        np.testing.assert_allclose(d_k, d_r, rtol=1e-6, atol=1e-6)

    def test_deterministic(self):
        y = rand(2, 16, 16)
        s = jnp.ones((16, 1)) * 5
        z = jnp.zeros((16, 1))
        a = rn_quant(y, s, z, 15.0)
        b = rn_quant(y, s, z, 15.0)
        np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


class TestQmatmul:
    @settings(max_examples=25, deadline=None)
    @given(m=dims, k=dims, n=dims, seed=st.integers(0, 1000))
    def test_matches_ref(self, m, k, n, seed):
        a = jax.random.normal(jax.random.PRNGKey(seed), (m, k))
        b = jax.random.normal(jax.random.PRNGKey(seed + 1), (k, n))
        got = qmatmul(a, b)
        want = ref.matmul_ref(a, b)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    @settings(max_examples=12, deadline=None)
    @given(
        bm=st.sampled_from([1, 2, 16, 64]),
        bk=st.sampled_from([1, 8, 32]),
        bn=st.sampled_from([1, 4, 64]),
    )
    def test_blocked_accumulation(self, bm, bk, bn):
        """k-inner accumulation over many blocks stays exact-ish."""
        a = rand(1, 64, 64)
        b = rand(2, 64, 64)
        got = qmatmul(a, b, bm=bm, bk=bk, bn=bn)
        want = ref.matmul_ref(a, b)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_ragged_dims_fall_back_to_divisors(self):
        """288 = 2^5*9: block picker must tile exactly (interpret mode
        NaN-fills out-of-bounds reads; a ragged tile would poison the
        accumulation — regression test for the CNN stage-2 NaN)."""
        a = rand(4, 96, 288)
        b = rand(5, 288, 33)
        got = qmatmul(a, b, bm=2048, bk=512, bn=512)
        assert not bool(jnp.isnan(got).any())
        np.testing.assert_allclose(got, ref.matmul_ref(a, b), rtol=1e-4, atol=1e-4)

    def test_prime_dims(self):
        a = rand(6, 127, 131)
        b = rand(7, 131, 113)
        got = qmatmul(a, b)
        np.testing.assert_allclose(got, ref.matmul_ref(a, b), rtol=1e-4, atol=1e-4)

    def test_transpose_helpers(self):
        from compile.kernels import qmatmul_nt, qmatmul_tn

        a = rand(8, 32, 16)
        g = rand(9, 32, 24)
        np.testing.assert_allclose(
            qmatmul_tn(a, g), ref.matmul_ref(a.T, g), rtol=1e-4, atol=1e-4
        )
        b = rand(10, 24, 16)
        np.testing.assert_allclose(
            qmatmul_nt(g, b.T), ref.matmul_ref(g, b), rtol=1e-4, atol=1e-4
        )


class TestRowStats:
    def test_rowstats_ref_shapes(self):
        x = rand(20, 6, 9)
        lo, hi = ref.rowstats_ref(x)
        assert lo.shape == (6, 1) and hi.shape == (6, 1)
        assert bool((hi >= lo).all())
