# L2 quantizer properties: unbiasedness (Thm 1's requirement on Q_b),
# the paper's variance bounds (Eq. 9, §4.1, §4.2), BHQ group construction
# invariants (App. D.5), and the extension formats.
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import quantizers as Q

jax.config.update("jax_platform_name", "cpu")


def outlier_matrix(key, n, d, big=10.0, small=0.01):
    """One huge row + tiny rest — the §4.2 gradient structure."""
    x = jax.random.normal(jax.random.PRNGKey(key), (n, d))
    scales = jnp.concatenate(
        [jnp.full((1, 1), big), jnp.full((n - 1, 1), small)], axis=0
    )
    return x * scales


def empirical_var(fn, x, reps=200):
    tot = 0.0
    for i in range(reps):
        out = fn(x, jax.random.PRNGKey(i))
        tot += float(jnp.sum((out - x) ** 2))
    return tot / reps


def empirical_bias(fn, x, reps=400):
    acc = jnp.zeros_like(x)
    for i in range(reps):
        acc = acc + fn(x, jax.random.PRNGKey(i))
    return float(jnp.abs(acc / reps - x).max())


BITS4 = Q.nbins(4.0)


class TestPTQ:
    def test_unbiased(self):
        x = outlier_matrix(0, 8, 16)
        f = jax.jit(lambda x, k: Q.ptq_stoch(x, k, BITS4))
        assert empirical_bias(f, x) < 0.35  # bin ~ R/15 ~ 2.7; SE ~ bin/sqrt(12*400)

    def test_variance_below_bound(self):
        x = outlier_matrix(1, 8, 16)
        f = jax.jit(lambda x, k: Q.ptq_stoch(x, k, BITS4))
        v = empirical_var(f, x)
        assert v <= float(Q.ptq_variance_bound(x, BITS4))

    def test_values_on_grid(self):
        x = outlier_matrix(2, 4, 8)
        out = Q.ptq_stoch(x, jax.random.PRNGKey(0), BITS4)
        lo = jnp.min(x)
        s = BITS4 / (jnp.max(x) - lo)
        codes = np.asarray((out - lo) * s)
        np.testing.assert_allclose(codes, np.round(codes), atol=1e-3)

    def test_det_forward_idempotent(self):
        x = outlier_matrix(3, 4, 8)
        a = Q.ptq_det(x, 255.0)
        b = Q.ptq_det(a, 255.0)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


class TestPSQ:
    def test_unbiased(self):
        x = outlier_matrix(4, 8, 16)
        f = jax.jit(lambda x, k: Q.psq(x, k, BITS4))
        assert empirical_bias(f, x) < 0.35

    def test_variance_below_bound_and_below_ptq(self):
        x = outlier_matrix(5, 12, 16)
        fp = jax.jit(lambda x, k: Q.ptq_stoch(x, k, BITS4))
        fs = jax.jit(lambda x, k: Q.psq(x, k, BITS4))
        vp, vs = empirical_var(fp, x), empirical_var(fs, x)
        assert vs <= float(Q.psq_variance_bound(x, BITS4)) * 1.05
        assert vs < vp / 3.0, (vs, vp)

    def test_tiny_rows_near_exact(self):
        """Correctly-classified samples (range ~ 0) are reproduced almost
        exactly — the §4.1 motivation."""
        x = outlier_matrix(6, 8, 32, big=5.0, small=1e-4)
        out = Q.psq(x, jax.random.PRNGKey(0), BITS4)
        err_small = float(jnp.abs(out[1:] - x[1:]).max())
        # per-row bin = R(row)/B; rows are N(0, small^2) so R ~ 4-5*small
        row_ranges = jnp.max(x[1:], axis=1) - jnp.min(x[1:], axis=1)
        assert err_small <= float(row_ranges.max()) / 15 * 1.01


class TestBHQGroups:
    @settings(max_examples=20, deadline=None)
    @given(n=st.sampled_from([2, 4, 8, 16, 64]), seed=st.integers(0, 10**6))
    def test_partition(self, n, seed):
        mags = jnp.sort(
            jnp.abs(jax.random.normal(jax.random.PRNGKey(seed), (n,)))
        )[::-1]
        gid, g = Q.bhq_groups(mags, n)
        gid = np.asarray(gid)
        g = int(g)
        assert 1 <= g <= n
        # leaders own their id; members point at a valid leader
        for i in range(n):
            if i < g:
                assert gid[i] == i
            else:
                assert 0 <= gid[i] < g

    def test_single_outlier_prefers_one_group(self):
        mags = jnp.asarray([10.0] + [0.001] * 31)
        _, g = Q.bhq_groups(mags, 32)
        assert int(g) == 1

    def test_two_outliers_prefer_two_groups(self):
        mags = jnp.asarray([10.0, 9.5] + [0.001] * 30)
        _, g = Q.bhq_groups(mags, 32)
        assert int(g) == 2


class TestBHQ:
    def test_householder_orthogonal_symmetric(self):
        x = outlier_matrix(7, 16, 8)
        mags = jnp.max(jnp.abs(x), axis=1)
        order = jnp.argsort(-mags)
        gid, _ = Q.bhq_groups(mags[order], 16)
        _, q = Q._bhq_matrices(x[order], gid, BITS4)
        eye = jnp.eye(16)
        assert float(jnp.abs(q @ q - eye).max()) < 1e-5  # involution
        assert float(jnp.abs(q - q.T).max()) < 1e-6  # symmetric

    def test_unbiased(self):
        x = outlier_matrix(8, 8, 16)
        f = jax.jit(lambda x, k: Q.bhq(x, k, BITS4))
        assert empirical_bias(f, x) < 0.4

    def test_beats_psq_on_outlier(self):
        x = outlier_matrix(9, 16, 32, big=10.0, small=0.001)
        fb = jax.jit(lambda x, k: Q.bhq(x, k, BITS4))
        fs = jax.jit(lambda x, k: Q.psq(x, k, BITS4))
        vb, vs = empirical_var(fb, x), empirical_var(fs, x)
        assert vb < vs / 2.0, (vb, vs)

    def test_range_constraint_after_transform(self):
        """R(S X) <= B (problem 12's constraint) for the chosen scales."""
        x = outlier_matrix(10, 16, 8)
        mags = jnp.max(jnp.abs(x), axis=1)
        order = jnp.argsort(-mags)
        xs = x[order]
        gid, _ = Q.bhq_groups(mags[order], 16)
        srow, q = Q._bhq_matrices(xs, gid, BITS4)
        y = q @ (srow * xs)
        rr = float((jnp.max(y, axis=1) - jnp.min(y, axis=1)).max())
        assert rr <= float(BITS4) * 1.01, rr

    def test_identity_on_uniform_high_bits(self):
        x = jax.random.normal(jax.random.PRNGKey(11), (8, 8))
        out = Q.bhq(x, jax.random.PRNGKey(0), Q.nbins(8.0))
        rel = float(jnp.sum((out - x) ** 2) / jnp.sum(x**2))
        assert rel < 1e-3


class TestExtensionFormats:
    def test_fp8_unbiased_and_finite(self):
        x = outlier_matrix(12, 4, 16, big=2.0, small=0.3)
        f = jax.jit(lambda x, k: Q.fp8_sim(x, k))
        assert empirical_bias(f, x, reps=600) < 0.05
        out = f(x, jax.random.PRNGKey(0))
        assert bool(jnp.isfinite(out).all())

    def test_bfp_unbiased(self):
        x = outlier_matrix(13, 4, 96, big=1.0, small=0.5)
        f = jax.jit(lambda x, k: Q.bfp(x, k, Q.nbins(8.0)))
        assert empirical_bias(f, x, reps=600) < 0.02

    def test_bfp_ragged_blocks(self):
        x = jax.random.normal(jax.random.PRNGKey(14), (3, 70))
        out = Q.bfp(x, jax.random.PRNGKey(0), Q.nbins(8.0), block=32)
        assert out.shape == x.shape
        assert bool(jnp.isfinite(out).all())


class TestDispatch:
    @settings(max_examples=10, deadline=None)
    @given(kind=st.sampled_from(Q.GRAD_QUANTIZERS))
    def test_shape_preserved(self, kind):
        g = jax.random.normal(jax.random.PRNGKey(15), (24, 10))
        out = Q.quantize_grad(kind, g, jax.random.PRNGKey(0), Q.nbins(6.0))
        assert out.shape == g.shape

    def test_sample_view_reshape(self):
        """Conv gradients: (N*positions, C) quantized in the (N, D) view."""
        g = jax.random.normal(jax.random.PRNGKey(16), (32, 10))  # N=8, pos=4
        out = Q.quantize_grad(
            "psq", g, jax.random.PRNGKey(0), Q.nbins(6.0), sample_count=8
        )
        assert out.shape == g.shape

    def test_unknown_kind_raises(self):
        with pytest.raises(ValueError):
            Q.quantize_grad(
                "nope", jnp.zeros((2, 2)), jax.random.PRNGKey(0), 15.0
            )


class TestVarianceLaws:
    def test_four_x_per_bit(self):
        """Eq. 10 discussion: each fewer bit ~4x the variance."""
        x = outlier_matrix(17, 8, 32, big=1.0, small=1.0)
        vars_ = []
        for bits in [4.0, 5.0, 6.0]:
            f = jax.jit(lambda x, k, b=bits: Q.ptq_stoch(x, k, Q.nbins(b)))
            vars_.append(empirical_var(f, x, reps=150))
        for hi, lo in zip(vars_, vars_[1:]):
            assert 2.5 < hi / lo < 6.0, vars_

    def test_sr_exact_variance_formula(self):
        t = jnp.asarray([[0.5, 0.25, 0.9, 3.0]])
        want = 0.25 + 0.25 * 0.75 + 0.9 * 0.1 * 0 + 0  # p(1-p) terms
        # recompute directly
        p = t - jnp.floor(t)
        want = float(jnp.sum(p * (1 - p)))
        got = float(Q.sr_exact_variance(t))
        assert abs(got - want) < 1e-6
